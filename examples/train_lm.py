"""End-to-end LM training driver: data pipeline -> pipelined wave steps ->
WSP sync -> checkpoints, with resume — all declared as a repro.api Plan.
Presets:

  demo (default) ~2M params, a few hundred waves in ~2 min on CPU
  100m           a ~100M-param qwen3-family config (the assignment's
                 "train ~100M model" example; same code path, more patience
                 or a real accelerator)

  PYTHONPATH=src python examples/train_lm.py --waves 300
  PYTHONPATH=src python examples/train_lm.py --preset 100m --waves 200
"""
import argparse
import os

import numpy as np

from repro.api import ClusterSpec, Engine, Plan, RunSpec, WSP
from repro.configs import ARCHS, reduced
from repro.optim import make_optimizer, warmup_cosine

PRESETS = {
    # ~2M params: quick CPU demo
    "demo": dict(num_layers=4, d_model=128, d_ff=256, vocab_size=512,
                 num_heads=4, num_kv_heads=2, head_dim=32,
                 num_microbatches=4),
    # ~100M params (qwen3 family): 12L x 768, vocab 32k
    "100m": dict(num_layers=12, d_model=768, d_ff=2048, vocab_size=32768,
                 num_heads=12, num_kv_heads=4, head_dim=64,
                 num_microbatches=4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="demo")
    ap.add_argument("--waves", type=int, default=300)
    ap.add_argument("--num-vw", type=int, default=2)
    ap.add_argument("--D", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/hetpipe_lm_ckpt")
    a = ap.parse_args()

    cfg = reduced(ARCHS["qwen3-0.6b"], **PRESETS[a.preset])
    print(f"preset={a.preset} params={cfg.param_count()/1e6:.1f}M "
          f"vw={a.num_vw} D={a.D}")

    plan = Plan(
        arch=cfg,
        cluster=ClusterSpec(num_vw=a.num_vw),
        sync=WSP(D=a.D),
        run=RunSpec(max_waves=a.waves, batch=a.batch, seq=a.seq,
                    ckpt_dir=a.ckpt, ckpt_every=25, resume=True))
    # a schedule the RunSpec's (optimizer, lr) strings cannot express is
    # injected — the Engine builds the wave step around it
    opt = make_optimizer("momentum", warmup_cosine(0.1, 20, a.waves))
    eng = Engine(plan, optimizer=opt)

    rep = eng.fit()
    t, loss = rep.loss_curve()
    k = max(4, len(loss) // 20)
    print(f"waves={rep.waves} wall={rep.wall_s:.1f}s "
          f"loss {np.mean(loss[:k]):.4f} -> {np.mean(loss[-k:]):.4f}")
    print(f"PS traffic: pushed={rep.bytes_pushed/1e6:.1f}MB "
          f"(one aggregated push per wave — the WSP saving)")
    if os.path.isdir(a.ckpt):
        print(f"checkpoints in {a.ckpt}: {sorted(os.listdir(a.ckpt))[-3:]}")
    else:
        print(f"no checkpoint yet (first one lands at wave 25)")


if __name__ == "__main__":
    main()
