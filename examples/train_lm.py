"""End-to-end LM training driver: data pipeline -> pipelined wave steps ->
WSP sync -> checkpoints, with resume. Presets:

  demo (default) ~2M params, a few hundred waves in ~2 min on CPU
  100m           a ~100M-param qwen3-family config (the assignment's
                 "train ~100M model" example; same code path, more patience
                 or a real accelerator)

  PYTHONPATH=src python examples/train_lm.py --waves 300
  PYTHONPATH=src python examples/train_lm.py --preset 100m --waves 200
"""
import argparse
import os

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.wave import build_local_wave_step
from repro.models import lm
from repro.optim import make_optimizer, warmup_cosine
from repro.runtime.checkpoint import latest_checkpoint, load_checkpoint
from repro.runtime.trainer import WSPTrainer

PRESETS = {
    # ~2M params: quick CPU demo
    "demo": dict(num_layers=4, d_model=128, d_ff=256, vocab_size=512,
                 num_heads=4, num_kv_heads=2, head_dim=32,
                 num_microbatches=4),
    # ~100M params (qwen3 family): 12L x 768, vocab 32k
    "100m": dict(num_layers=12, d_model=768, d_ff=2048, vocab_size=32768,
                 num_heads=12, num_kv_heads=4, head_dim=64,
                 num_microbatches=4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="demo")
    ap.add_argument("--waves", type=int, default=300)
    ap.add_argument("--num-vw", type=int, default=2)
    ap.add_argument("--D", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/hetpipe_lm_ckpt")
    a = ap.parse_args()

    cfg = reduced(ARCHS["qwen3-0.6b"], **PRESETS[a.preset])
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(np.size(x) for x in jax.tree.leaves(params))
    print(f"preset={a.preset} params={n_params/1e6:.1f}M "
          f"vw={a.num_vw} D={a.D}")

    opt = make_optimizer("momentum",
                         warmup_cosine(0.1, 20, a.waves))
    step = build_local_wave_step(cfg, cfg.num_microbatches, opt)

    path = latest_checkpoint(a.ckpt)
    if path:
        out, meta = load_checkpoint(path, {"params": params})
        params = out["params"]
        print(f"resumed from {path} (wave {meta['step']})")

    tr = WSPTrainer(params, step, opt, num_vw=a.num_vw, D=a.D,
                    batch=a.batch, seq=a.seq, vocab=cfg.vocab_size,
                    max_waves=a.waves, ckpt_dir=a.ckpt, ckpt_every=25)
    rep = tr.run()
    t, loss = rep.loss_curve()
    k = max(4, len(loss) // 20)
    print(f"waves={rep.waves} wall={rep.wall_s:.1f}s "
          f"loss {np.mean(loss[:k]):.4f} -> {np.mean(loss[-k:]):.4f}")
    print(f"PS traffic: pushed={rep.bytes_pushed/1e6:.1f}MB "
          f"(one aggregated push per wave — the WSP saving)")
    print(f"checkpoints in {a.ckpt}: {sorted(os.listdir(a.ckpt))[-3:]}")


if __name__ == "__main__":
    main()
