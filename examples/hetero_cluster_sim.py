"""The paper's heterogeneous-cluster experiment, end to end on CPU:

1. allocate the paper's GPU fleet (Table 1: V/R/G/Q x4) into virtual workers
   under NP / ED / HD (Table 3), partition the model per VW (Section 7),
2. run REAL WSP training with per-VW speeds derived from the allocation
   (stragglers emerge exactly as in the paper), BSP-AllReduce as baseline,
3. report throughput ratios and the D-sweep (Figures 4-6 analogue).

  PYTHONPATH=src python examples/hetero_cluster_sim.py
"""
import numpy as np

from repro.api import BSP, ClusterSpec, Engine, Plan, RunSpec, WSP
from repro.configs import ARCHS, reduced
from repro.core.allocation import Node, allocate, vw_throughputs, \
    straggler_report, straggler_report_comm
from repro.core.partition import PAPER_GPUS
from repro.dist.topology import ClusterTopology

NODES = [Node(PAPER_GPUS[c], 4) for c in "VRGQ"]
MODEL = ARCHS["h2o-danube-1.8b"]          # stand-in for the paper's VGG-19

print("== allocation policies (analytic, paper Fig. 4 / Table 3) ==")
policy_speed = {}
for pol in ("NP", "ED", "HD"):
    vws = allocate(NODES, pol)
    th = vw_throughputs(MODEL, vws, 4096, 4 * 4096, nm=4)
    rep = straggler_report(th)
    policy_speed[pol] = th
    names = ["".join(g.name.split()[-1][0] for g in vw) for vw in vws]
    print(f"  {pol}: vws={names} imbalance={rep['imbalance']:.2f} "
          f"bsp={rep['bsp_rate']:.0f} wsp={rep['wsp_rate']:.0f} img/s")

print("\n== comm-aware straggling (10G Ethernet to the PS, Section 7) ==")
topo = ClusterTopology.from_fleet(NODES, num_vw=4)
th_hd = policy_speed["HD"]
rep_c = straggler_report_comm(th_hd, topo,
                              bytes_per_wave=MODEL.param_count() * 4 * 0.01)
print(f"  HD: compute-only imbalance={rep_c['compute_only']['imbalance']:.2f}"
      f" -> with network {rep_c['imbalance']:.2f} "
      f"(per-VW push s: {[round(c, 3) for c in rep_c['comm_seconds']]})")

print("\n== real WSP training with NP-induced straggling (Figs. 5/6) ==")
cfg = reduced(MODEL, num_layers=2, d_model=32, d_ff=64, vocab_size=256,
              num_heads=2, num_kv_heads=2, head_dim=16, num_microbatches=2,
              window_size=0, attn_type="full")
# per-VW slowdowns proportional to the NP allocation's speed imbalance;
# infeasible VWs (zero throughput — the model does not fit) get a fixed
# large straggle instead of an infinite one
th = policy_speed["NP"]
slow = [0.1 * (th.max() / t - 1.0) if t > 0 else 0.5 for t in th]
print(f"  per-VW extra seconds/wave: {[round(s, 3) for s in slow]}")

# one Plan per scenario: identical model/fleet/run, only the SyncPolicy moves
base = Plan(arch=cfg,
            cluster=ClusterSpec(num_vw=4, speeds=slow),
            sync=BSP(),
            run=RunSpec(max_waves=8, batch=4, seq=32))
rep_bsp = Engine(base).fit()
for D in (0, 4):
    rep = Engine(base.replace(sync=WSP(D=D))).fit()
    t, loss = rep.loss_curve()
    waits = np.mean(list(rep.wait_seconds.values()))
    print(f"  WSP D={D}: wall={rep.wall_s:5.1f}s final_loss="
          f"{np.mean(loss[-6:]):.3f} mean_wait={waits:.2f}s")
t, loss = rep_bsp.loss_curve()
print(f"  BSP     : wall={rep_bsp.wall_s:5.1f}s final_loss="
      f"{np.mean(loss[-6:]):.3f}  (straggler-gated)")
