"""Quickstart: HetPipe in 25 lines — declare a Plan, run it with the Engine.

Two virtual workers train one model through the WSP parameter server (D=1),
on CPU, in seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import ClusterSpec, Engine, Plan, RunSpec, WSP
from repro.configs import ARCHS, reduced

# a tiny qwen3-family model (the full config is ARCHS["qwen3-0.6b"])
cfg = reduced(ARCHS["qwen3-0.6b"], num_layers=2, d_model=32, d_ff=64,
              vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=16,
              num_microbatches=2)

plan = Plan(
    arch=cfg,
    cluster=ClusterSpec(num_vw=2),       # two virtual workers (DP)
    sync=WSP(D=1),                       # global staleness bound
    run=RunSpec(max_waves=15, batch=8, seq=32, optimizer="sgd", lr=0.3),
)

# each wave = Nm pipelined minibatches; one aggregated push per wave (WSP)
report = Engine(plan).fit()

t, loss = report.loss_curve()
print(f"waves={report.waves}  loss {loss[0]:.3f} -> {np.mean(loss[-4:]):.3f}"
      f"  wall={report.wall_s:.1f}s  pushed={report.bytes_pushed/1e6:.1f}MB")
assert np.mean(loss[-4:]) < loss[0], "did not learn"
print("OK — see examples/train_lm.py for the full driver, "
      "repro.api.presets for canonical scenarios")
