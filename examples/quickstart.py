"""Quickstart: HetPipe in 40 lines — two virtual workers training one model
through the WSP parameter server (D=1), on CPU, in seconds.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.wave import build_local_wave_step
from repro.models import lm
from repro.optim import make_optimizer
from repro.runtime.trainer import WSPTrainer

# a tiny qwen3-family model (the full config is ARCHS["qwen3-0.6b"])
cfg = reduced(ARCHS["qwen3-0.6b"], num_layers=2, d_model=32, d_ff=64,
              vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=16,
              num_microbatches=2)

params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
opt = make_optimizer("sgd", 0.3)

# each wave = Nm pipelined minibatches; one aggregated push per wave (WSP)
wave_step = build_local_wave_step(cfg, cfg.num_microbatches, opt)

trainer = WSPTrainer(params, wave_step, opt,
                     num_vw=2,          # two virtual workers (DP)
                     D=1,               # global staleness bound
                     batch=8, seq=32, vocab=cfg.vocab_size, max_waves=15)
report = trainer.run()

t, loss = report.loss_curve()
print(f"waves={report.waves}  loss {loss[0]:.3f} -> {np.mean(loss[-4:]):.3f}"
      f"  wall={report.wall_s:.1f}s  pushed={report.bytes_pushed/1e6:.1f}MB")
assert np.mean(loss[-4:]) < loss[0], "did not learn"
print("OK — see examples/train_lm.py for the full driver")
