"""Batched serving example: prefill a batch of prompts, then decode
autoregressively with KV/SSM caches — across three architecture families
(dense GQA, sliding-window, attention-free RWKV6).

  PYTHONPATH=src python examples/serve_batched.py
"""
import subprocess
import sys
import os

HERE = os.path.dirname(os.path.abspath(__file__))

for arch in ("qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-3b"):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
         "--batch", "4", "--prompt-len", "24", "--gen", "12"],
        env={**os.environ,
             "PYTHONPATH": os.path.join(HERE, "..", "src")},
        capture_output=True, text=True, timeout=900)
    sys.stdout.write(r.stdout)
    if r.returncode:
        sys.stderr.write(r.stderr[-2000:])
        raise SystemExit(f"{arch} failed")
print("OK")
