"""Batched serving example: prefill a batch of prompts, then decode
autoregressively with KV/SSM caches — across three architecture families
(dense GQA, sliding-window, attention-free RWKV6), all routed through the
repro.api serve surface (Plan + Engine.generate()), plus one
continuous-batching run through the request scheduler.

  PYTHONPATH=src python examples/serve_batched.py
"""
import subprocess
import sys
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ENV = {**os.environ, "PYTHONPATH": os.path.join(HERE, "..", "src")}


def run(extra):
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve"] + extra,
        env=ENV, capture_output=True, text=True, timeout=900)
    sys.stdout.write(r.stdout)
    if r.returncode:
        sys.stderr.write(r.stderr[-2000:])
        raise SystemExit(f"{extra} failed")


# aligned-batch generate() on each family
for arch in ("qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-3b"):
    run(["--arch", arch, "--batch", "4", "--prompt-len", "24",
         "--gen", "12"])

# continuous batching: 6 requests through 2 decode slots
run(["--arch", "qwen3-0.6b", "--requests", "6", "--batch", "2",
     "--prompt-len", "16", "--gen", "8"])

# paged KV: 4-token pages, pool below the worst case, deadline admission
run(["--arch", "qwen3-0.6b", "--requests", "6", "--batch", "2",
     "--prompt-len", "16", "--gen", "8", "--page-size", "4",
     "--max-pages", "10", "--policy", "deadline"])
print("OK")
