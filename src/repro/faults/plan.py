"""Deterministic, seeded fault scenarios.

A `FaultPlan` is a frozen tuple of typed fault events, each anchored to a
*logical* index — a worker's wave number, a directed link's message
counter, the PS's push count, the scheduler's decode step — never to wall
clock. Two runs of the same Plan therefore inject byte-identical fault
sequences regardless of host timing, which is what makes the chaos suite's
determinism assertions possible.

Events:

  LinkFault       outage / degradation / probabilistic loss window on the
                  directed (src, dst) path, in units of that path's message
                  counter. An outage fails `n_msgs` consecutive *attempts*
                  (retries re-enter the window until it expires), degrade
                  multiplies the modeled cost, loss drops each attempt with
                  probability p (seeded per path — deterministic).
  WorkerCrash     the virtual worker dies at the start of wave `wave`
                  WITHOUT deregistering (a dead node cannot say goodbye) —
                  detection and eviction are the supervisor's job.
  WorkerSlowdown  from wave `wave` on, the worker takes `extra_s` longer
                  per wave (slowdown onset — the flapping/whimpy case).
  PSStall         the parameter server sleeps `seconds` before applying
                  push number `at_push` (a stalled PS shard).
  SlotFault       serving: the decode-batch slot `slot` faults at decode
                  step `step` (its transient per-slot state is lost; the
                  Scheduler quarantines the slot and recovers the request).
  ReplicaDown     serving: replica `replica` of a data-parallel serve
                  fleet (partition.data > 1) dies at *its own* decode step
                  `step`; the Router re-dispatches its unfinished requests
                  onto the survivors (requeue semantics — replay from the
                  prompt, bit-identical streams).

`FaultPolicy` holds the recovery knobs: transport retry/backoff budgets,
heartbeat-driven eviction and rejoin of workers, degraded-completion
opt-in, and the serve-side retry budget / load shedding. It lives on the
Plan next to the FaultPlan (`Plan.faults` / `Plan.fault_policy`), so a
scenario's failures and its recovery posture are validated together.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LinkFault:
    src: str                    # message source endpoint ('vw0', 'ps', ...)
    dst: str                    # destination endpoint
    start_msg: int = 0          # first affected attempt index on this path
    n_msgs: int = 1             # window length, in attempts
    kind: str = "outage"        # 'outage' | 'degrade' | 'loss'
    factor: float = 10.0        # degrade: modeled-cost multiplier
    p: float = 0.5              # loss: per-attempt drop probability

    def validate(self) -> None:
        if self.kind not in ("outage", "degrade", "loss"):
            raise ValueError(f"unknown LinkFault kind {self.kind!r}; "
                             f"expected outage | degrade | loss")
        if self.start_msg < 0 or self.n_msgs < 1:
            raise ValueError(f"LinkFault window [{self.start_msg}, "
                             f"+{self.n_msgs}) must be non-negative and "
                             f"non-empty")
        if self.kind == "degrade" and self.factor <= 0:
            raise ValueError(f"degrade factor must be > 0, got {self.factor}")
        if self.kind == "loss" and not 0.0 <= self.p <= 1.0:
            raise ValueError(f"loss probability must be in [0, 1], "
                             f"got {self.p}")


@dataclass(frozen=True)
class WorkerCrash:
    vw: int                     # virtual worker index
    wave: int                   # dies at the start of this wave

    def validate(self) -> None:
        if self.vw < 0 or self.wave < 0:
            raise ValueError(f"WorkerCrash(vw={self.vw}, wave={self.wave}) "
                             f"must be non-negative")


@dataclass(frozen=True)
class WorkerSlowdown:
    vw: int
    wave: int = 0               # onset wave
    extra_s: float = 0.2        # extra seconds per wave from onset on

    def validate(self) -> None:
        if self.vw < 0 or self.wave < 0 or self.extra_s < 0:
            raise ValueError(f"WorkerSlowdown(vw={self.vw}, "
                             f"wave={self.wave}, extra_s={self.extra_s}) "
                             f"must be non-negative")


@dataclass(frozen=True)
class PSStall:
    at_push: int                # stall before applying this push number
    seconds: float = 0.1

    def validate(self) -> None:
        if self.at_push < 0 or self.seconds < 0:
            raise ValueError(f"PSStall(at_push={self.at_push}, "
                             f"seconds={self.seconds}) must be non-negative")


@dataclass(frozen=True)
class SlotFault:
    slot: int                   # decode-batch slot index
    step: int                   # global decode step the fault fires at

    def validate(self) -> None:
        if self.slot < 0 or self.step < 0:
            raise ValueError(f"SlotFault(slot={self.slot}, "
                             f"step={self.step}) must be non-negative")


@dataclass(frozen=True)
class ReplicaDown:
    replica: int                # Router replica index (partition.data)
    step: int                   # the replica's own decode step

    def validate(self) -> None:
        if self.replica < 0 or self.step < 0:
            raise ValueError(f"ReplicaDown(replica={self.replica}, "
                             f"step={self.step}) must be non-negative")


TRAIN_EVENTS = (LinkFault, WorkerCrash, WorkerSlowdown, PSStall)
SERVE_EVENTS = (SlotFault, ReplicaDown)


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultPlan:
    """A frozen, validated set of fault events plus the seed that keys any
    probabilistic decision (message-loss draws)."""

    seed: int = 0
    events: tuple = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        self.validate()

    def validate(self) -> None:
        known = TRAIN_EVENTS + SERVE_EVENTS
        for ev in self.events:
            if not isinstance(ev, known):
                raise TypeError(f"unknown fault event {ev!r}; expected one "
                                f"of {[c.__name__ for c in known]}")
            ev.validate()

    def of_type(self, *kinds) -> list:
        return [e for e in self.events if isinstance(e, kinds)]

    def describe(self) -> str:
        by = {}
        for e in self.events:
            by[type(e).__name__] = by.get(type(e).__name__, 0) + 1
        inner = ", ".join(f"{k}x{v}" for k, v in sorted(by.items()))
        return f"FaultPlan(seed={self.seed}, {inner or 'empty'})"

    # ---- seeded scenario generators -----------------------------------
    @staticmethod
    def sample_train(seed: int, *, num_vw: int, max_waves: int,
                     with_crash: bool = True) -> "FaultPlan":
        """A deterministic random training chaos scenario: one VW crash
        (mid-run), one link-outage window on that worker's push path, one
        slowdown onset on another worker, and one PS stall."""
        rng = np.random.default_rng(seed)
        events = []
        crash_vw = int(rng.integers(0, num_vw))
        if with_crash and num_vw > 1:
            wave = int(rng.integers(1, max(2, max_waves // 2)))
            events.append(WorkerCrash(vw=crash_vw, wave=wave))
        victim = int(rng.integers(0, num_vw))
        events.append(LinkFault(src=f"vw{victim}", dst="ps",
                                start_msg=int(rng.integers(0, 3)),
                                n_msgs=int(rng.integers(1, 4)),
                                kind="outage"))
        if num_vw > 1:
            slow = (crash_vw + 1) % num_vw
            events.append(WorkerSlowdown(vw=slow,
                                         wave=int(rng.integers(0, 2)),
                                         extra_s=0.01))
        events.append(PSStall(at_push=int(rng.integers(0, max_waves)),
                              seconds=0.01))
        return FaultPlan(seed=seed, events=tuple(events))

    @staticmethod
    def sample_serve(seed: int, *, max_batch: int,
                     n_faults: int = 1) -> "FaultPlan":
        """A deterministic random serving chaos scenario: `n_faults` slot
        faults in the first few decode steps."""
        rng = np.random.default_rng(seed)
        events = tuple(SlotFault(slot=int(rng.integers(0, max_batch)),
                                 step=int(rng.integers(1, 5)) + 3 * i)
                       for i in range(n_faults))
        return FaultPlan(seed=seed, events=events)

    @staticmethod
    def sample_cluster(seed: int, *, replicas: int) -> "FaultPlan":
        """A deterministic random cluster chaos scenario: one replica of a
        data-parallel serve fleet dies early in its decode loop, forcing
        the Router to re-dispatch its unfinished requests."""
        rng = np.random.default_rng(seed)
        return FaultPlan(seed=seed, events=(
            ReplicaDown(replica=int(rng.integers(0, replicas)),
                        step=int(rng.integers(1, 4))),))


# ---------------------------------------------------------------------------
# recovery policy
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class FaultPolicy:
    """How the runtime responds to faults. All time knobs are in *modeled*
    seconds (scaled by ClusterSpec.time_scale before sleeping, like every
    other simulated delay)."""

    # -- transport: per-message timeout + capped exponential backoff ----
    msg_timeout_s: float = 0.05     # modeled cost of one failed attempt
    max_retries: int = 8            # re-attempts before TransportError
    backoff_base_s: float = 0.01    # backoff = base * 2^retry, capped
    backoff_cap_s: float = 0.25

    # -- WSP gate ---------------------------------------------------------
    gate_timeout_s: float = 120.0   # host seconds at the staleness gate

    # -- fleet supervision: heartbeat-driven eviction + rejoin ------------
    # A worker's heartbeat is its WSP clock. The supervisor evicts a worker
    # when its clock lags the fleet max by >= evict_lag waves AND it is
    # either dead (thread exited) or has not advanced for stall_grace_s —
    # the lag (clock currency) is the detector, the grace only debounces
    # live-but-slow workers. evict_lag <= D guarantees detection fires
    # before survivors deadlock at the gate. 0 disables eviction.
    evict_lag: int = 0
    stall_grace_s: float = 1.0
    # A worker at clock 0 has not finished its first wave, which includes
    # jit compilation — an unpredictable, seconds-scale cost that would trip
    # stall_grace_s on a perfectly healthy fleet. Until the first wave lands
    # the stall detector uses this (much larger) grace instead.
    startup_grace_s: float = 60.0
    heartbeat_every_s: float = 0.05  # supervisor poll cadence (host s)
    # Rejoin an evicted/crashed worker once the global clock has advanced
    # `rejoin_after_waves` waves past its eviction point (deterministic,
    # clock currency), or after `rejoin_delay_s` host seconds — whichever
    # is set; None disables that trigger. Each worker rejoins at most
    # rejoin_max times.
    rejoin_after_waves: int | None = None
    rejoin_delay_s: float | None = None
    rejoin_max: int = 1

    # -- degraded completion ---------------------------------------------
    # fit() raises DegradedRunError when the run ends with gate timeouts
    # or unrecovered dead workers; True returns the TrainReport instead
    # (with the fault counters filled in).
    allow_degraded: bool = False

    # -- serving ----------------------------------------------------------
    slot_retry_budget: int = 1      # re-admissions per faulted request
    slot_recovery: str = "requeue"  # 'requeue' (replay from the prompt) |
                                    # 'reprefill' (rebuild the slot from
                                    # its still-mapped pages, keep tokens)
    quarantine_slots: bool = True   # faulted slots leave the free pool
    shed_after_faults: int = 0      # >0: refuse new admissions after N
                                    # slot faults (graceful load shedding)

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        for name in ("msg_timeout_s", "backoff_base_s", "backoff_cap_s",
                     "gate_timeout_s", "stall_grace_s", "startup_grace_s",
                     "heartbeat_every_s"):
            if getattr(self, name) < 0:
                raise ValueError(f"FaultPolicy.{name} must be >= 0")
        for name in ("max_retries", "evict_lag", "rejoin_max",
                     "slot_retry_budget", "shed_after_faults"):
            if getattr(self, name) < 0:
                raise ValueError(f"FaultPolicy.{name} must be >= 0")
        if self.rejoin_after_waves is not None and self.rejoin_after_waves < 0:
            raise ValueError("FaultPolicy.rejoin_after_waves must be >= 0")
        if self.rejoin_delay_s is not None and self.rejoin_delay_s < 0:
            raise ValueError("FaultPolicy.rejoin_delay_s must be >= 0")
        if self.slot_recovery not in ("requeue", "reprefill"):
            raise ValueError(f"unknown slot_recovery "
                             f"{self.slot_recovery!r}; expected 'requeue' "
                             f"or 'reprefill'")

    @property
    def rejoins(self) -> bool:
        return (self.rejoin_after_waves is not None
                or self.rejoin_delay_s is not None) and self.rejoin_max > 0
