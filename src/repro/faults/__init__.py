"""repro.faults — deterministic fault injection and elastic recovery.

The WSP proof (paper Section 4) bounds staleness for whatever set of
virtual workers is *live*, which means the system should tolerate slow,
flapping and dead workers by design. This package makes that claim
testable:

  plan        frozen, seeded `FaultPlan` (link outages/degradation,
              message loss, worker crash/slowdown onset, PS stalls, serve
              slot faults) + the `FaultPolicy` recovery knobs
  injector    the plan compiled into O(1) runtime lookups, consulted at
              the three seams: SimulatedTransport (per-message verdicts),
              ParameterServer (push stalls), Scheduler (slot faults)
  supervisor  heartbeat-driven eviction of dead/stalled workers from the
              WSP clock + elastic rejoin from the PS's atomic state
  errors      typed failures: TransportError, PushTimeout, GateTimeout,
              DegradedRunError

Attach a scenario to a Plan with `Plan(faults=FaultPlan(...),
fault_policy=FaultPolicy(...))`; every injected fault and recovery action
lands in the repro.obs trace so `repro.obs.summary` can audit that
recovery respected the staleness bound D.
"""
from repro.faults.errors import (DegradedRunError, FaultError, GateTimeout,
                                 PushTimeout, TransportError)
from repro.faults.injector import FaultInjector
from repro.faults.plan import (FaultPlan, FaultPolicy, LinkFault, PSStall,
                               ReplicaDown, SlotFault, WorkerCrash,
                               WorkerSlowdown)
from repro.faults.supervisor import Eviction, FleetSupervisor

__all__ = [
    "DegradedRunError", "Eviction", "FaultError", "FaultInjector",
    "FaultPlan", "FaultPolicy", "FleetSupervisor", "GateTimeout",
    "LinkFault", "PSStall", "PushTimeout", "ReplicaDown", "SlotFault",
    "TransportError", "WorkerCrash", "WorkerSlowdown",
]
