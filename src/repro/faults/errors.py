"""Typed fault errors.

Every failure mode the runtime can recover from gets a named exception, so
callers dispatch on type instead of sentinel booleans: the WSP staleness
gate timing out (`GateTimeout`), a push whose transport retries are
exhausted (`PushTimeout`), a message the (simulated) network lost for good
(`TransportError`), and a run that finished with unrecovered failures
(`DegradedRunError` — raised by Engine.fit() unless the Plan's FaultPolicy
opts into degraded completion).

All inherit FaultError, so "any injectable/recoverable failure" is one
except clause; anything else escaping a worker is a programming error and
still propagates loudly.
"""
from __future__ import annotations


class FaultError(RuntimeError):
    """Base class for recoverable, fault-subsystem failures."""


class TransportError(FaultError):
    """A message exhausted its retry budget on a (simulated) link."""

    def __init__(self, src: str, dst: str, link: str, attempts: int,
                 nbytes: int):
        self.src, self.dst, self.link = src, dst, link
        self.attempts, self.nbytes = attempts, nbytes
        super().__init__(
            f"{src}->{dst} ({link}): message of {nbytes} bytes lost after "
            f"{attempts} attempts (retry budget exhausted)")


class PushTimeout(FaultError):
    """A wave push never landed: its wire transfer failed terminally."""

    def __init__(self, wid: str, cause: Exception):
        self.wid, self.cause = wid, cause
        super().__init__(f"{wid}: wave push did not land: {cause}")


class GateTimeout(FaultError):
    """The WSP staleness gate never opened within the timeout — some other
    virtual worker stopped advancing the global clock."""

    def __init__(self, wid: str, wave: int, waited_s: float):
        self.wid, self.wave, self.waited_s = wid, wave, waited_s
        super().__init__(
            f"{wid}: staleness gate for wave {wave} never opened within "
            f"{waited_s:.1f}s — a peer stopped advancing the global clock "
            f"(crashed or stalled worker; enable FaultPolicy eviction to "
            f"recover survivors)")


class DegradedRunError(FaultError):
    """fit() completed with unrecovered failures (gate timeouts, dead
    workers with no successful rejoin). Carries the TrainReport so the
    partial result is inspectable."""

    def __init__(self, msg: str, report=None):
        self.report = report
        super().__init__(msg)
