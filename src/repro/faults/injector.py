"""Runtime fault injection: the FaultPlan compiled into O(1) lookups.

One `FaultInjector` is built per Engine run and threaded through the three
seams the ISSUE names: the transport (per-attempt message verdicts), the
parameter server (push-apply stalls) and the scheduler (slot faults). All
decisions key on logical indices the injector tracks itself — per-path
attempt counters, push counts, decode steps — so two runs of the same
Plan inject identical fault sequences.

Message-loss draws are stateless: attempt `a` on path (src, dst) hashes
(plan.seed, crc32(path), a) into a fresh Generator, so a retry (a new
attempt index) gets an independent draw and the sequence never depends on
thread interleaving across paths.
"""
from __future__ import annotations

import threading
import zlib
from collections import defaultdict

import numpy as np

from repro.faults.plan import (FaultPlan, LinkFault, PSStall, SlotFault,
                               WorkerCrash, WorkerSlowdown)


class FaultInjector:
    def __init__(self, plan: FaultPlan | None, *, time_scale: float = 1.0):
        self.plan = plan if plan is not None else FaultPlan()
        self.time_scale = float(time_scale)
        self._lock = threading.Lock()
        self._msg_idx: dict[tuple, int] = defaultdict(int)
        # per-kind lookups
        self._link: dict[tuple, list[LinkFault]] = defaultdict(list)
        self._crash: dict[int, int] = {}
        self._slow: dict[int, WorkerSlowdown] = {}
        self._ps_stall: dict[int, float] = {}
        self._slot: dict[int, list[int]] = defaultdict(list)
        for ev in self.plan.events:
            if isinstance(ev, LinkFault):
                self._link[(ev.src, ev.dst)].append(ev)
            elif isinstance(ev, WorkerCrash):
                # earliest crash wins if several name the same worker
                w = self._crash.get(ev.vw)
                self._crash[ev.vw] = ev.wave if w is None else min(w, ev.wave)
            elif isinstance(ev, WorkerSlowdown):
                self._slow.setdefault(ev.vw, ev)
            elif isinstance(ev, PSStall):
                self._ps_stall[ev.at_push] = max(
                    self._ps_stall.get(ev.at_push, 0.0), ev.seconds)
            elif isinstance(ev, SlotFault):
                self._slot[ev.step].append(ev.slot)

    # ---- transport seam ---------------------------------------------------
    def _attempt_verdict(self, path: tuple, a: int) -> tuple[bool, float]:
        """(ok, cost_factor) for attempt index `a` on `path`."""
        ok, factor = True, 1.0
        for ev in self._link.get(path, ()):
            if not ev.start_msg <= a < ev.start_msg + ev.n_msgs:
                continue
            if ev.kind == "outage":
                ok = False
            elif ev.kind == "degrade":
                factor *= ev.factor
            elif ev.kind == "loss":
                key = (self.plan.seed,
                       zlib.crc32(f"{path[0]}->{path[1]}".encode()), a)
                if np.random.default_rng(key).random() < ev.p:
                    ok = False
        return ok, factor

    def message_attempts(self, src: str, dst: str,
                         max_attempts: int) -> list[tuple[bool, float]]:
        """Consume up to `max_attempts` attempt indices on the (src, dst)
        path and return their (ok, cost_factor) verdicts, stopping after
        the first success. The empty-plan fast path returns a single clean
        attempt without touching the counter."""
        path = (src, dst)
        if path not in self._link:
            return [(True, 1.0)]
        out = []
        with self._lock:        # one message's attempts stay contiguous
            for _ in range(max_attempts):
                a = self._msg_idx[path]
                self._msg_idx[path] += 1
                v = self._attempt_verdict(path, a)
                out.append(v)
                if v[0]:
                    break
        return out

    # ---- worker seam ------------------------------------------------------
    def crash_wave(self, vw: int) -> int | None:
        return self._crash.get(vw)

    def slowdown_extra(self, vw: int, wave: int) -> float:
        """Extra host seconds of compute for `vw` at `wave` (modeled
        slowdown scaled like every other simulated delay)."""
        ev = self._slow.get(vw)
        if ev is None or wave < ev.wave:
            return 0.0
        return ev.extra_s * self.time_scale

    # ---- parameter-server seam -------------------------------------------
    def ps_stall_sleep(self, push_idx: int) -> float:
        """Host seconds to sleep before applying push number `push_idx`
        (modeled stall scaled like every other simulated delay)."""
        return self._ps_stall.get(push_idx, 0.0) * self.time_scale

    # ---- scheduler seam ---------------------------------------------------
    def slot_faults(self, step: int) -> list[int]:
        return self._slot.get(step, [])

    @property
    def empty(self) -> bool:
        return not self.plan.events
