"""Heartbeat-driven fleet supervision: eviction + elastic rejoin.

The supervisor watches the threaded WSP fleet from the Engine's
supervision loop. A worker's *heartbeat is its WSP clock* — the number of
waves it has landed — so failure detection runs in the protocol's own
currency rather than wall time:

  dead    the worker thread exited without deregistering (an injected
          WorkerCrash models a node that vanishes mid-run and cannot say
          goodbye). Evicted as soon as detected: a dead worker's clock
          pins the global minimum forever, so every survivor would
          otherwise stall at the staleness gate within D waves.
  stalled the worker is alive but its clock lags the fleet max by
          >= evict_lag waves and has not advanced for stall_grace_s (the
          grace only debounces merely-slow workers). With evict_lag <= D
          the lag threshold is reached *before* survivors deadlock at the
          gate — the whole point of detecting in clock units.

Eviction deregisters the worker from the WSP clock (its clock leaves the
global min — the paper's proof is parameterized by the live worker count,
so survivors keep training at bounded staleness) and flags the thread to
exit at its next gate. An in-flight async push from an evicted worker may
still land: the ParameterServer applies the delta (a stale-but-sound
gradient) but never advances the clock of a deregistered worker
(`late_pushes`), so eviction can never push a survivor past its D window.

Rejoin spawns a successor worker (`vw{i}r`, `vw{i}rr`, ...) once the
policy's trigger fires — the global clock advancing rejoin_after_waves
past the eviction point (deterministic), or rejoin_delay_s host seconds —
up to rejoin_max times per worker. The successor registers at the current
global clock and pulls w_global, which is exactly the PS state an atomic
checkpoint (ParameterServer.checkpoint_state) would hand a re-provisioned
node; its traffic is aliased onto the failed worker's topology endpoint.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.faults.plan import FaultPolicy
from repro.obs import NULL_TRACER


@dataclass
class _WorkerWatch:
    clock: int = 0
    last_advance: float = field(default_factory=time.monotonic)


@dataclass
class Eviction:
    wid: str
    at_clock: int               # global clock when evicted
    reason: str                 # 'dead' | 'stalled' | 'crashed'
    t: float = field(default_factory=time.monotonic)
    rejoined: int = 0


class FleetSupervisor:
    """Polled from the Engine's supervision loop; owns evict/rejoin state.

    `spawn(index, wid)` builds, registers and starts a successor worker
    (the Engine provides it so the supervisor stays runtime-agnostic)."""

    def __init__(self, ps, workers: dict, policy: FaultPolicy, *,
                 spawn: Optional[Callable[[int, str], object]] = None,
                 topology=None, tracer=None):
        self.ps = ps
        self.workers = workers
        self.policy = policy
        self.spawn = spawn
        self.topology = topology
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._watch: dict[str, _WorkerWatch] = {}
        self.evictions: list[Eviction] = []
        self.rejoins: list[str] = []
        self._handled: set[str] = set()

    # ------------------------------------------------------------------
    @staticmethod
    def base_index(wid: str) -> int:
        """'vw2rr' -> 2: the original fleet index a successor maps onto."""
        return int(wid[2:].rstrip("r"))

    def _evict(self, wid: str, worker, reason: str) -> None:
        self._handled.add(wid)
        ev = Eviction(wid, at_clock=self.ps.clock.global_clock(),
                      reason=reason)
        self.evictions.append(ev)
        if reason != "crashed":       # crashed = already deregistered itself
            if worker is not None:
                worker.evict()
            self.ps.deregister(wid)
        self.tracer.instant("supervisor", "evict", wid=wid, reason=reason,
                            at_clock=ev.at_clock)
        self.tracer.metrics.counter_inc("fault/evictions")

    def _try_rejoin(self, ev: Eviction) -> None:
        pol = self.policy
        if self.spawn is None or not pol.rejoins \
                or ev.rejoined >= pol.rejoin_max:
            return
        due = False
        if pol.rejoin_after_waves is not None:
            due |= (self.ps.clock.global_clock()
                    >= ev.at_clock + pol.rejoin_after_waves)
        if pol.rejoin_delay_s is not None:
            due |= time.monotonic() - ev.t >= pol.rejoin_delay_s
        if not due:
            return
        ev.rejoined += 1
        new_wid = ev.wid + "r"
        if new_wid in self.workers:     # successor also died; chain the name
            while new_wid in self.workers:
                new_wid += "r"
        i = self.base_index(ev.wid)
        if self.topology is not None and f"vw{i}" in self.topology.pod_of:
            # the successor lives on the failed worker's node as far as
            # the network model is concerned — its traffic lands on the
            # same links
            self.topology.add_alias(new_wid, f"vw{i}")
        w = self.spawn(i, new_wid)
        self.rejoins.append(new_wid)
        self.tracer.instant("supervisor", "rejoin", wid=new_wid,
                            for_wid=ev.wid,
                            at_clock=self.ps.clock.global_clock())
        self.tracer.metrics.counter_inc("fault/rejoins")
        return w

    def pending_rejoin(self) -> bool:
        """True while some eviction still owes a rejoin that is guaranteed
        to eventually fire — the Engine's supervision loop keeps running
        for these even after every thread has exited. A wave-triggered
        rejoin whose clock condition cannot advance anymore only counts
        when it is already due (it would otherwise spin forever)."""
        pol = self.policy
        if self.spawn is None or not pol.rejoins:
            return False
        for ev in self.evictions:
            if ev.rejoined >= pol.rejoin_max:
                continue
            if pol.rejoin_delay_s is not None:
                return True
            if pol.rejoin_after_waves is not None and \
                    self.ps.clock.global_clock() \
                    >= ev.at_clock + pol.rejoin_after_waves:
                return True
        return False

    # ------------------------------------------------------------------
    def poll(self) -> None:
        """One supervision pass: heartbeat bookkeeping, eviction of dead /
        stalled workers, rejoin of the evicted."""
        pol = self.policy
        clocks = dict(self.ps.clock.state.clocks)
        fleet_max = max(clocks.values()) if clocks else 0
        now = time.monotonic()
        for wid, worker in list(self.workers.items()):
            if wid in self._handled:
                continue
            registered = wid in clocks
            if not registered:
                if worker.failed and not worker.is_alive():
                    # deregistered itself on the way down (graceful crash:
                    # fail_at / transport exhaustion) — eligible for rejoin
                    self._evict(wid, worker, "crashed")
                continue
            watch = self._watch.setdefault(wid, _WorkerWatch(clocks[wid]))
            if clocks[wid] != watch.clock:
                watch.clock, watch.last_advance = clocks[wid], now
            if pol.evict_lag <= 0:
                continue
            if getattr(worker, "done", False):
                # finished its waves; its clock legitimately stops — not a
                # failure, never evict
                continue
            if not worker.is_alive():
                # dead without goodbye: its clock pins the global minimum
                # forever — evict unconditionally
                self._evict(wid, worker, "dead")
                continue
            lag = fleet_max - clocks[wid]
            # clock 0 = first wave still running, which includes jit
            # compile — debounce with the (much larger) startup grace so a
            # healthy fleet mid-compile is never evicted
            grace = pol.startup_grace_s if clocks[wid] == 0 \
                else pol.stall_grace_s
            if lag >= pol.evict_lag and \
                    now - watch.last_advance >= grace:
                self._evict(wid, worker, "stalled")
        for ev in self.evictions:
            self._try_rejoin(ev)
