"""Thread-safe tracer: spans, instant events and counter samples.

Events carry (track, name, timestamps, args). A *track* is the Perfetto row
the event renders on — one per virtual worker, pipeline stage, network link,
scheduler, engine — so the exported trace reads like the cluster: wave
compute per VW, pushes in flight on the links, pipeline bubbles per stage.

Timestamps come from an injectable clock (default time.monotonic). A
simulated run that scales modeled delays (`ClusterSpec.time_scale`) can
inject a clock in the same scaled currency so the trace reads in modeled
time rather than host wall time.

Disabled tracing is free: `NULL_TRACER` (and any `Tracer(enabled=False)`)
returns the shared `NULL_SPAN` singleton from span() and falls through
every other method without allocating or locking, so instrumentation can
stay unconditionally in hot paths. The attached MetricsRegistry shares the
enabled flag.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from repro.obs.metrics import MetricsRegistry


class _NullSpan:
    """Shared no-op context manager returned by disabled tracers."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tr", "_track", "_name", "_args", "_t0")

    def __init__(self, tr: "Tracer", track: str, name: str, args: dict):
        self._tr, self._track, self._name, self._args = tr, track, name, args

    def __enter__(self):
        self._t0 = self._tr.now()
        return self

    def __exit__(self, *exc):
        self._tr.add_span(self._track, self._name, self._t0, self._tr.now(),
                          **self._args)
        return False


class Tracer:
    """Collects trace events; export via repro.obs.export / Tracer.export.

    Event tuples are (ph, track, name, t0_s, dur_s, args) with ph one of
    'X' (span), 'i' (instant), 'C' (counter sample: args {name: value}).
    """

    def __init__(self, *, enabled: bool = True,
                 clock: Optional[Callable[[], float]] = None):
        self.enabled = enabled
        self._clock = clock if clock is not None else time.monotonic
        self.metrics = MetricsRegistry(enabled=enabled)
        self._events: list = []
        self._lock = threading.Lock()

    # -- time --------------------------------------------------------------
    def now(self) -> float:
        return self._clock()

    # -- recording ---------------------------------------------------------
    def span(self, track: str, name: str, **args):
        """Context manager timing a region onto `track`."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, track, name, args)

    def add_span(self, track: str, name: str, t0: float, t1: float,
                 **args) -> None:
        """Record an already-timed [t0, t1) interval."""
        if not self.enabled:
            return
        with self._lock:
            self._events.append(("X", track, name, t0, max(0.0, t1 - t0),
                                 args))

    def instant(self, track: str, name: str, **args) -> None:
        if not self.enabled:
            return
        t = self.now()
        with self._lock:
            self._events.append(("i", track, name, t, 0.0, args))

    def counter(self, track: str, name: str, value: float) -> None:
        """Sample a counter series (rendered as a counter track)."""
        if not self.enabled:
            return
        t = self.now()
        with self._lock:
            self._events.append(("C", track, name, t, 0.0, {name: value}))

    # -- scoping -----------------------------------------------------------
    def scoped(self, prefix: str) -> "Tracer":
        """A view of this tracer that prepends `prefix` to every track name
        — the Router gives each serve replica `scoped('r{i}/')` so one
        exported trace reads like the fleet ('r0/sched', 'r1/sched', ...).
        Events, the lock, the clock and the MetricsRegistry are shared with
        the parent; disabled tracers return themselves (still free)."""
        if not self.enabled:
            return self
        return _ScopedTracer(self, prefix)

    # -- reading -----------------------------------------------------------
    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def __bool__(self) -> bool:
        # len() measures recorded events, but a tracer is not a container:
        # `if tracer:` must not silently flip on the first recorded event
        return True

    # -- export ------------------------------------------------------------
    def export(self, path: str, *, telemetry: Optional[dict] = None) -> str:
        """Write Chrome-trace-event JSON (Perfetto-loadable). The metrics
        snapshot (or the given `telemetry` dict) rides along under the
        top-level 'telemetry' key for the summary CLI and CI audits."""
        from repro.obs.export import write_chrome
        tel = telemetry if telemetry is not None else self.metrics.snapshot()
        return write_chrome(self.events(), path, telemetry=tel)


class _ScopedTracer(Tracer):
    """Track-prefixing view over a parent Tracer (see Tracer.scoped).

    Shares the parent's event list, lock, clock and metrics — only track
    names change, so the parent's export() sees every scoped event and
    metrics stay fleet-global (counters from all replicas accumulate in
    one registry)."""

    def __init__(self, parent: Tracer, prefix: str):
        # deliberately NOT calling super().__init__: this view delegates
        # to the parent (whose _Span objects bind to the parent, so the
        # prefix is applied exactly once), rather than owning fresh state
        self._parent = parent
        self._prefix = prefix
        self.enabled = parent.enabled
        self.metrics = parent.metrics

    def now(self) -> float:
        return self._parent.now()

    def span(self, track: str, name: str, **args):
        return self._parent.span(self._prefix + track, name, **args)

    def add_span(self, track: str, name: str, t0: float, t1: float,
                 **args) -> None:
        self._parent.add_span(self._prefix + track, name, t0, t1, **args)

    def instant(self, track: str, name: str, **args) -> None:
        self._parent.instant(self._prefix + track, name, **args)

    def counter(self, track: str, name: str, value: float) -> None:
        self._parent.counter(self._prefix + track, name, value)

    def events(self) -> list:
        return self._parent.events()

    def __len__(self) -> int:
        return len(self._parent)

    def export(self, path: str, *, telemetry: Optional[dict] = None) -> str:
        return self._parent.export(path, telemetry=telemetry)

    def scoped(self, prefix: str) -> "Tracer":
        return _ScopedTracer(self._parent, self._prefix + prefix)


NULL_TRACER = Tracer(enabled=False)


def emit_pipeline_ticks(tracer: Tracer, track_prefix: str, schedule,
                        ticks: int, t0: float, t1: float) -> None:
    """Render one wave's pipeline schedule as per-stage tick spans.

    `schedule` is core.wave.tick_schedule output: (stage, tick, mb) entries
    with mb < 0 marking bubble ticks. The wave's measured [t0, t1) window is
    divided evenly over `ticks`; each stage gets its own track
    (`{track_prefix}/stage{s}`) carrying `mb{j}` compute spans and `bubble`
    spans. Busy/bubble seconds accumulate into the metrics counters
    `pipe/busy_s` / `pipe/bubble_s` (bubble fraction = bubble/(busy+bubble)).

    The schedule is the *modeled* intra-VW pipeline (what the wave step
    executes on its k GPUs); on the threads backend the wave step runs the
    sequential oracle, so these tracks visualize the Plan's schedule scaled
    into the wave's measured duration rather than per-tick measurements.
    """
    if not tracer.enabled or ticks <= 0:
        return
    dt = (t1 - t0) / ticks
    busy = 0
    for stage, tick, mb in schedule:
        a = t0 + tick * dt
        name = "bubble" if mb < 0 else f"mb{mb}"
        tracer.add_span(f"{track_prefix}/stage{stage}", name, a, a + dt,
                        tick=tick)
        if mb >= 0:
            busy += 1
    tracer.metrics.counter_inc("pipe/busy_s", busy * dt)
    tracer.metrics.counter_inc("pipe/bubble_s", (len(schedule) - busy) * dt)
