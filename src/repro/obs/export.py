"""Chrome-trace-event JSON export (the format Perfetto / chrome://tracing
load) plus the schema validator tests and CI share.

The exported document is the standard object form:

    {"traceEvents": [...], "displayTimeUnit": "ms", "telemetry": {...}}

One process (pid 1); each tracer track becomes one thread row (tid assigned
in first-appearance order) named via 'M' thread_name metadata, so Perfetto
shows one labeled row per VW / stage / link / scheduler. Spans are complete
('X') events, instants 'i', counter samples 'C'. Timestamps are in
microseconds relative to the earliest event (Chrome's expected unit).
"""
from __future__ import annotations

import json

PID = 1


def to_chrome(events, *, telemetry=None) -> dict:
    """events: Tracer event tuples (ph, track, name, t0_s, dur_s, args)."""
    tids: dict[str, int] = {}
    out = [{"ph": "M", "name": "process_name", "pid": PID, "tid": 0,
            "args": {"name": "repro"}}]
    t_base = min((e[3] for e in events), default=0.0)

    def tid(track: str) -> int:
        if track not in tids:
            tids[track] = len(tids) + 1
            out.append({"ph": "M", "name": "thread_name", "pid": PID,
                        "tid": tids[track], "args": {"name": track}})
        return tids[track]

    for ph, track, name, t0, dur, args in sorted(events, key=lambda e: e[3]):
        ev = {"ph": ph, "name": name, "cat": "repro", "pid": PID,
              "tid": tid(track), "ts": (t0 - t_base) * 1e6,
              "args": dict(args)}
        if ph == "X":
            ev["dur"] = dur * 1e6
        elif ph == "i":
            ev["s"] = "t"                  # thread-scoped instant
        out.append(ev)
    doc = {"traceEvents": out, "displayTimeUnit": "ms"}
    if telemetry is not None:
        doc["telemetry"] = telemetry
    return doc


def write_chrome(events, path: str, *, telemetry=None) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome(events, telemetry=telemetry), f)
    return path


def validate_chrome(doc) -> None:
    """Raise ValueError unless `doc` is well-formed Chrome trace JSON of the
    shape this exporter writes (the contract Perfetto ingestion needs)."""
    def fail(msg):
        raise ValueError(f"invalid Chrome trace: {msg}")

    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail("top level must be an object with a 'traceEvents' list")
    evs = doc["traceEvents"]
    if not isinstance(evs, list) or not evs:
        fail("'traceEvents' must be a non-empty list")
    named_tids = set()
    for ev in evs:
        if not isinstance(ev, dict):
            fail(f"event is not an object: {ev!r}")
        for key in ("ph", "name", "pid"):
            if key not in ev:
                fail(f"event missing {key!r}: {ev!r}")
        ph = ev["ph"]
        if ph not in ("M", "X", "i", "C"):
            fail(f"unknown event phase {ph!r}")
        if ph == "M":
            if ev["name"] == "thread_name":
                named_tids.add(ev["tid"])
            continue
        if not isinstance(ev.get("ts"), (int, float)) or ev["ts"] < 0:
            fail(f"event needs a non-negative numeric ts: {ev!r}")
        if ph == "X" and (not isinstance(ev.get("dur"), (int, float))
                          or ev["dur"] < 0):
            fail(f"'X' event needs a non-negative numeric dur: {ev!r}")
        if not isinstance(ev.get("args", {}), dict):
            fail(f"args must be an object: {ev!r}")
        if ev.get("tid") not in named_tids:
            fail(f"event on unnamed track tid={ev.get('tid')!r}")
    tel = doc.get("telemetry")
    if tel is not None:
        if not isinstance(tel, dict):
            fail("'telemetry' must be an object")
        for section in ("counters", "gauges", "histograms"):
            if section in tel and not isinstance(tel[section], dict):
                fail(f"telemetry.{section} must be an object")


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    validate_chrome(doc)
    return doc
