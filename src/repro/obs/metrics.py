"""Metrics: counters, gauges and fixed-bucket histograms.

A MetricsRegistry is the aggregate side of the observability substrate (the
Tracer is the event side): cheap thread-safe accumulation during a run,
snapshotted once at report-assembly time into `repro.api.report.Telemetry`.

Histograms use fixed upper-edge buckets (`bounds`) plus an overflow bucket,
and additionally track the exact min/max/sum/count — so audits that must be
exact (the WSP staleness bound: measured max <= Plan D) never depend on
bucket resolution, while quantiles resolve to a bucket upper edge.

A registry built with enabled=False is a true no-op: every method returns
immediately without taking the lock or allocating.
"""
from __future__ import annotations

import threading
from typing import Optional, Sequence

# default bucket edges by metric flavor: small non-negative integers
# (staleness, queue depths) and log-spaced seconds (latencies)
INT_BOUNDS = tuple(range(0, 17))
SECONDS_BOUNDS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
                  0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


class Histogram:
    """Fixed-bucket histogram with exact min/max/sum/count sidecars."""

    __slots__ = ("bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, bounds: Sequence[float] = SECONDS_BOUNDS):
        self.bounds = tuple(float(b) for b in bounds)
        assert self.bounds == tuple(sorted(self.bounds)), "bounds must ascend"
        self.counts = [0] * (len(self.bounds) + 1)   # last = overflow
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        i = 0
        for b in self.bounds:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-resolution quantile: the upper edge of the bucket holding
        the q-th sample (the exact max for the overflow bucket)."""
        if not self.count:
            return None
        target = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c:
                return self.bounds[i] if i < len(self.bounds) else self.vmax
        return self.vmax

    def snapshot(self) -> dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "count": self.count, "sum": self.total,
                "min": self.vmin, "max": self.vmax}


def quantile_from_snapshot(snap: dict, q: float) -> Optional[float]:
    """Histogram.quantile over a snapshot() dict — lets report/bench code
    compute p50/p99 from exported telemetry without a live Histogram."""
    if not snap or not snap.get("count"):
        return None
    bounds = snap["bounds"]
    target = q * snap["count"]
    seen = 0
    for i, c in enumerate(snap["counts"]):
        seen += c
        if seen >= target and c:
            return bounds[i] if i < len(bounds) else snap["max"]
    return snap["max"]


class MetricsRegistry:
    """Thread-safe named counters, gauges and histograms."""

    def __init__(self, *, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}

    def counter_inc(self, name: str, v: float = 1.0) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + v

    def gauge_set(self, name: str, v: float) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._gauges[name] = float(v)

    def observe(self, name: str, v: float,
                bounds: Sequence[float] = SECONDS_BOUNDS) -> None:
        if not self.enabled:
            return
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(bounds)
            h.observe(v)

    def histogram(self, name: str) -> Optional[Histogram]:
        with self._lock:
            return self._hists.get(name)

    def snapshot(self) -> dict:
        """Plain-dict state: {'counters', 'gauges', 'histograms'} — the
        payload Telemetry.from_metrics wraps and the trace export embeds."""
        with self._lock:
            return {"counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "histograms": {n: h.snapshot()
                                   for n, h in self._hists.items()}}
