"""repro.obs — unified tracing + metrics for the whole stack.

One substrate observes every layer: the threaded WSP runtime (wave compute,
push flight, pull-gate waits, per-pull staleness samples audited against the
Plan's D), the parameter server (push apply, snapshots), the simulated
transport (per-link sends carrying modeled delay + bytes), the pipelined
wave schedule (per-stage tick/bubble tracks), the Engine surface
(fit/step/prefill/decode) and the continuous-batching Scheduler
(admit/refuse/prefill-group/decode-step/retire).

    from repro.obs import Tracer
    tr = Tracer()
    report = Engine(plan, tracer=tr).fit()
    tr.export("trace.json")            # Chrome trace JSON; open in Perfetto
    # report.telemetry: staleness histogram, bubble fraction, link stats

Everything accepts a disabled tracer (the NULL_TRACER singleton) and then
records nothing and allocates nothing on the hot path — instrumented code
never needs a None check, and an untraced run is bit-identical to a traced
one (tracing observes timing, never the data path).
"""
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import (NULL_SPAN, NULL_TRACER, Tracer,
                             emit_pipeline_ticks)

__all__ = [
    "Histogram", "MetricsRegistry", "NULL_SPAN", "NULL_TRACER", "Tracer",
    "emit_pipeline_ticks",
]
