"""Text summary of an exported trace: the CLI companion to Perfetto.

    PYTHONPATH=src python -m repro.obs.summary trace.json

Prints per-track busy time, the WSP staleness histogram (audited against
the recorded D bound when present), the pipeline bubble summary, per-link
traffic/utilization, the fault/recovery counters (repro.faults: drops,
retries, crashes, evictions vs rejoins) and serve TTFT — everything the
ROADMAP's measurement items report through. Exits non-zero on a malformed
trace or a staleness audit failure — chaos runs included: an injected
fault whose recovery broke the D bound fails the audit here.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict

from repro.obs.export import load


def _fmt_s(s: float) -> str:
    return f"{s * 1e3:.1f}ms" if s < 1.0 else f"{s:.2f}s"


def _hist_line(h: dict) -> str:
    pairs = []
    edges = list(h["bounds"]) + ["inf"]
    for edge, c in zip(edges, h["counts"]):
        if c:
            pairs.append(f"<={edge}:{c}")
    return " ".join(pairs) if pairs else "(empty)"


def summarize(doc: dict) -> list[str]:
    lines = []
    tracks: dict[int, str] = {}
    busy = defaultdict(float)
    span_count = defaultdict(int)
    t_lo, t_hi = None, 0.0
    for ev in doc["traceEvents"]:
        if ev["ph"] == "M":
            if ev["name"] == "thread_name":
                tracks[ev["tid"]] = ev["args"]["name"]
            continue
        t_lo = ev["ts"] if t_lo is None else min(t_lo, ev["ts"])
        t_hi = max(t_hi, ev["ts"] + ev.get("dur", 0.0))
        if ev["ph"] == "X":
            name = tracks.get(ev["tid"], f"tid{ev['tid']}")
            busy[name] += ev["dur"] / 1e6
            span_count[name] += 1
    wall = ((t_hi - (t_lo or 0.0)) / 1e6) or 1e-9
    lines.append(f"trace: {len(doc['traceEvents'])} events, "
                 f"{len(tracks)} tracks, span {_fmt_s(wall)}")
    for name in sorted(busy, key=busy.get, reverse=True):
        lines.append(f"  {name:<24s} busy={_fmt_s(busy[name]):>9s} "
                     f"({min(1.0, busy[name] / wall):5.1%})  "
                     f"spans={span_count[name]}")

    tel = doc.get("telemetry") or {}
    hists = tel.get("histograms", {})
    gauges = tel.get("gauges", {})
    counters = tel.get("counters", {})

    st = hists.get("wsp/staleness")
    if st:
        d = gauges.get("wsp/D")
        bound = "" if d is None else (
            f"  bound D={d:g} -> {'OK' if st['max'] <= d else 'VIOLATED'}")
        lines.append(f"wsp staleness: n={st['count']} max={st['max']:g} "
                     f"mean={st['sum'] / max(1, st['count']):.2f}{bound}")
        lines.append(f"  hist: {_hist_line(st)}")
        if d is not None and st["max"] > d:
            raise ValueError(
                f"staleness audit failed: measured max {st['max']:g} exceeds "
                f"the Plan's D={d:g}")

    bub, comp = counters.get("pipe/bubble_s"), counters.get("pipe/busy_s")
    if comp:
        frac = bub / (bub + comp) if (bub or 0) + comp > 0 else 0.0
        lines.append(f"pipeline: busy={_fmt_s(comp)} "
                     f"bubble={_fmt_s(bub or 0.0)} "
                     f"bubble_fraction={frac:.1%}")

    links = sorted(k.split("/", 2)[1] for k in gauges
                   if k.startswith("link/") and k.endswith("/bytes"))
    for ln in links:
        b = gauges.get(f"link/{ln}/bytes", 0.0)
        s = gauges.get(f"link/{ln}/modeled_s", 0.0)
        util = min(1.0, s / wall)
        lines.append(f"link {ln:<18s} bytes={b / 1e6:8.2f}MB "
                     f"modeled={_fmt_s(s):>9s} util={util:5.1%}")

    faults = {k.split("/", 1)[1]: v for k, v in sorted(counters.items())
              if k.startswith("fault/")}
    if faults:
        lines.append("faults: " + " ".join(f"{k}={v:g}"
                                           for k, v in faults.items()))
        recovered = (faults.get("rejoins", 0) >= faults.get("evictions", 0)
                     and not faults.get("gate_timeouts", 0))
        lines.append(f"  recovery: "
                     f"{'complete' if recovered else 'partial/degraded'} "
                     f"(evictions={faults.get('evictions', 0):g} "
                     f"rejoins={faults.get('rejoins', 0):g} "
                     f"gate_timeouts={faults.get('gate_timeouts', 0):g})")

    ttft = hists.get("serve/ttft_s")
    if ttft:
        lines.append(f"serve ttft: n={ttft['count']} "
                     f"mean={_fmt_s(ttft['sum'] / max(1, ttft['count']))} "
                     f"max={_fmt_s(ttft['max'])}")
    hit = gauges.get("serve/prefix_hit_rate")
    if hit is not None:
        lines.append(f"serve memory: prefix_hit_rate={hit:.1%} "
                     f"evictions={counters.get('serve/evictions', 0):g} "
                     f"preemptions="
                     f"{counters.get('serve/preemptions', 0):g}")
    disp = counters.get("serve/router_dispatches")
    if disp:
        lines.append(f"serve router: dispatches={disp:g} "
                     f"affinity_hits="
                     f"{counters.get('serve/router_affinity_hits', 0):g} "
                     f"rebalances="
                     f"{counters.get('serve/router_rebalances', 0):g} "
                     f"queue_depth_peak="
                     f"{gauges.get('serve/router_queue_depth', 0):g} "
                     f"replica_downs="
                     f"{counters.get('fault/replica_downs', 0):g}")
    wt = hists.get("train/wait_s")
    if wt:
        lines.append(f"gate waits: n={wt['count']} "
                     f"total={_fmt_s(wt['sum'])} max={_fmt_s(wt['max'])}")
    return lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace JSON written by --trace / "
                                  "Tracer.export")
    a = ap.parse_args(argv)
    try:
        doc = load(a.trace)
        for line in summarize(doc):
            print(line)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
