"""Mixture-of-Experts: top-k routing with static capacity dispatch (TPU-friendly,
no dynamic shapes). Scatter-based dispatch keeps memory at O(T*k + E*C*d).

Expert compute is an expert-batched GEMM (einsum 'ecd,edgf->ecgf'), which the
Pallas grouped-matmul kernel (repro.kernels.moe_gmm) accelerates on TPU.

Weight layout (TP-shardable on the ff dim): w_in [E, d, G, ff], w_out [E, ff, d]
where G = 2 for gated MLPs (gate; up) and 1 otherwise.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def route_topk(logits, top_k: int):
    """logits [T, E] -> (weights [T,k], idx [T,k], aux_loss scalar)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(idx, E, dtype=jnp.float32)      # [T,k,E]
    fe = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)
    aux = E * jnp.sum(me * fe)
    return w, idx, aux


def _expert_ffn(h_in, w_in, w_out, mlp_type):
    h = jnp.einsum("ecd,edgf->ecgf", h_in, w_in.astype(h_in.dtype))
    if mlp_type == "swiglu":
        a = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    elif mlp_type == "geglu":
        a = jax.nn.gelu(h[..., 0, :], approximate=True) * h[..., 1, :]
    elif mlp_type == "gelu":
        a = jax.nn.gelu(h[..., 0, :], approximate=True)
    else:
        r = jax.nn.relu(h[..., 0, :])
        a = r * r
    return jnp.einsum("ecf,efd->ecd", a, w_out.astype(h_in.dtype))


def moe_mlp(p, x, *, num_experts: int, top_k: int, mlp_type: str,
            capacity_factor: float = 1.25, ep_axis: str | None = None):
    """x [B, S, d] -> ([B, S, d] partial if ff is tp-sharded, aux loss).

    p: router [d, E], w_in [E, d, G, ffl], w_out [E, ffl, d].
    ep_axis: optional mesh axis for expert parallelism — w_in/w_out then hold
    the local expert shard and tokens are exchanged with all_to_all.
    """
    B, S, d = x.shape
    T = B * S
    E, K = num_experts, top_k
    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    w, idx, aux = route_topk(logits, K)

    cap = int(max(K, round(T * K / E * capacity_factor)))
    cap = max(4, (cap + 3) // 4 * 4)

    # position of each (token, choice) within its expert queue
    flat_e = idx.reshape(-1)                                  # [T*K]
    one_hot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # [T*K, E]
    pos = jnp.cumsum(one_hot, axis=0) - 1                     # running count
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = slot < cap
    dest = jnp.where(keep, flat_e * cap + slot, E * cap)      # drop bucket

    buf = jnp.zeros((E * cap + 1, d), xf.dtype)
    src = jnp.repeat(xf, K, axis=0)                           # [T*K, d]
    buf = buf.at[dest].set(src)
    expert_in = buf[:-1].reshape(E, cap, d)

    if ep_axis is not None:
        n_shard = jax.lax.axis_size(ep_axis)
        expert_in = expert_in.reshape(n_shard, E // n_shard, cap, d)
        expert_in = jax.lax.all_to_all(expert_in, ep_axis, split_axis=0,
                                       concat_axis=2)
        expert_in = expert_in.reshape(E // n_shard, n_shard * cap, d)

    out = _expert_ffn(expert_in, p["w_in"], p["w_out"], mlp_type)

    if ep_axis is not None:
        n_shard = jax.lax.axis_size(ep_axis)
        out = out.reshape(E // n_shard, n_shard, cap, d).transpose(1, 0, 2, 3)
        out = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0)
        out = out.reshape(E, cap, d)

    flat_out = jnp.concatenate(
        [out.reshape(E * cap, d), jnp.zeros((1, d), out.dtype)], axis=0)
    gathered = flat_out[dest].reshape(T, K, d)
    combined = jnp.einsum("tkd,tk->td", gathered.astype(jnp.float32),
                          w * keep.reshape(T, K))
    return combined.reshape(B, S, d).astype(x.dtype), aux
