"""Model assembly: parameter init (with sharding specs), per-layer metadata,
stage application (used by the pipeline), KV/SSM caches, input specs, and a
plain non-pipelined reference forward (correctness oracle for the pipeline).

Parameter layout: block leaves are stacked over ALL layers on dim 0 with
`padded_layers = stages * layer_slots` slots, sharded over the "stage" mesh
axis (each pipeline stage receives its contiguous slice — the paper's
contiguous-layer partitions). TP dims are sharded over "tp".
"""
from __future__ import annotations

import math
from dataclasses import replace as dc_replace
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig, RunConfig
from repro.models.blocks import LayerCtx, apply_layer
from repro.models.layers import rms_norm, layer_norm, chunked_cross_entropy

S_AX, T_AX, D_AX = "stage", "tp", "data"


def padded_vocab(cfg: ArchConfig, mult: int = 16) -> int:
    """Vocab padded for 16-way (stage x tp) sharding (Megatron-style);
    padded logit columns are masked to -inf in the loss."""
    return (cfg.vocab_size + mult - 1) // mult * mult


# ----------------------------------------------------------------------------
# Layer metadata
# ----------------------------------------------------------------------------
def layer_meta(cfg: ArchConfig) -> dict[str, np.ndarray]:
    """Per-slot arrays, shaped [stages, slots] for stage-sharded consumption."""
    kinds = np.array(cfg.layer_kinds(), np.int32)
    Lp = cfg.padded_layers
    valid = np.arange(Lp) < cfg.num_layers
    kinds = np.where(valid, kinds, 2 if cfg.ssm_type == "rwkv6" else 0)
    full_i = np.zeros(Lp, np.int32)
    win_i = np.zeros(Lp, np.int32)
    st, sl = cfg.stages, cfg.layer_slots
    m_full = m_win = 0
    for s in range(st):
        nf = nw = 0
        for j in range(sl):
            l = s * sl + j
            if valid[l] and kinds[l] == 0 and cfg.attn_type != "none":
                full_i[l] = nf
                nf += 1
            elif valid[l] and kinds[l] == 1:
                win_i[l] = nw
                nw += 1
        m_full, m_win = max(m_full, nf), max(m_win, nw)
    rs = lambda a: a.reshape(st, sl)
    return dict(kind=rs(kinds), valid=rs(valid), full_i=rs(full_i),
                win_i=rs(win_i), m_full=m_full, m_win=m_win)


def uniform_kind(cfg: ArchConfig) -> Optional[int]:
    """Static layer kind if every (real) layer is identical, else None."""
    if cfg.num_layers % cfg.stages:
        return None
    if cfg.attn_type == "full":
        return 0
    if cfg.attn_type == "swa":
        return 1
    if cfg.attn_type == "none":
        return 2
    return None


# ----------------------------------------------------------------------------
# Parameter init + specs
# ----------------------------------------------------------------------------
def _block_shapes(cfg: ArchConfig) -> dict[str, tuple[tuple, P, str]]:
    """leaf -> (per-layer shape, spec (without the leading stage dim), init)."""
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G = 2 if cfg.mlp_type in ("swiglu", "geglu") else 1
    tp_ax = T_AX if cfg.tp > 1 else None
    kv_tp = tp_ax if (KV and cfg.tp > 1 and KV % cfg.tp == 0) else None
    out: dict[str, tuple[tuple, P, str]] = {}

    def norm(name):
        out[name] = ((d,), P(None), "zeros" if "rms" in cfg.norm_style
                     else "ones")
        if cfg.norm_style == "ln_pre":
            out[name + "_b"] = ((d,), P(None), "zeros")

    if cfg.ssm_type == "rwkv6":
        Hs, hds = cfg.n_ssm_heads, d // cfg.n_ssm_heads
        out["ln1"] = ((d,), P(None), "zeros")
        out["ln2"] = ((d,), P(None), "zeros")
        for m in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w"):
            out[m] = ((d,), P(None), "half")
        for w in ("wr", "wk", "wv", "wg"):
            out[w] = ((d, d), P(None, None), "normal")
        out["wo"] = ((d, d), P(None, None), "normal_out")
        out["w0"] = ((d,), P(None), "w0")
        out["wa"] = ((d, 64), P(None, None), "zeros")
        out["wb"] = ((64, d), P(None, None), "zeros")
        out["u"] = ((Hs, hds), P(None, None), "half")
        out["gn_scale"] = ((d,), P(None), "ones")
        out["gn_bias"] = ((d,), P(None), "zeros")
        out["cm_mu_k"] = ((d,), P(None), "half")
        out["cm_mu_r"] = ((d,), P(None), "half")
        out["cm_k"] = ((d, ff), P(None, None), "normal")
        out["cm_v"] = ((ff, d), P(None, None), "normal_out")
        out["cm_r"] = ((d, d), P(None, None), "normal")
        return out

    norm("ln1")
    out["wq"] = ((d, H * hd), P(None, tp_ax), "normal")
    out["wk"] = ((d, KV * hd), P(None, kv_tp), "normal")
    out["wv"] = ((d, KV * hd), P(None, kv_tp), "normal")
    out["wo"] = ((H * hd, d), P(tp_ax, None), "normal_out")
    if cfg.qk_norm:
        out["q_norm"] = ((hd,), P(None), "zeros")
        out["k_norm"] = ((hd,), P(None), "zeros")
    if cfg.norm_style == "rms_sandwich":
        out["ln1_post"] = ((d,), P(None), "zeros")
        out["ln2_post"] = ((d,), P(None), "zeros")
    norm("ln2")
    if cfg.num_experts:
        E = cfg.num_experts
        out["router"] = ((d, E), P(None, None), "normal")
        out["moe_w_in"] = ((E, d, G, ff), P(None, None, None, tp_ax), "normal")
        out["moe_w_out"] = ((E, ff, d), P(None, tp_ax, None), "normal_out")
    else:
        out["mlp_wi"] = ((d, G, ff), P(None, None, tp_ax), "normal")
        out["mlp_wo"] = ((ff, d), P(tp_ax, None), "normal_out")
    if cfg.hybrid_parallel:
        di, N, Hs = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
        out["bn_attn"] = ((d,), P(None), "zeros")
        out["bn_ssm"] = ((d,), P(None), "zeros")
        out["ssd_in_proj"] = ((d, 2 * di + 2 * N + Hs), P(None, None), "normal")
        out["ssd_conv_w"] = ((4, di + 2 * N), P(None, None), "normal")
        out["ssd_dt_bias"] = ((Hs,), P(None), "dt_bias")
        out["ssd_A_log"] = ((Hs,), P(None), "a_log")
        out["ssd_D"] = ((Hs,), P(None), "ones")
        out["ssd_norm_scale"] = ((di,), P(None), "zeros")
        out["ssd_out_proj"] = ((di, d), P(None, None), "normal_out")
    return out


def _init_leaf(key, shape, init, cfg: ArchConfig, dtype):
    if init == "zeros":
        return jnp.zeros(shape, dtype)
    if init == "ones":
        return jnp.ones(shape, dtype)
    if init == "half":
        return jnp.full(shape, 0.5, dtype)
    if init == "w0":
        return jnp.full(shape, -5.0, dtype)
    if init == "dt_bias":
        return jnp.full(shape, -4.6, dtype)
    if init == "a_log":
        return jnp.log(jnp.linspace(1.0, 16.0, shape[-1])).astype(dtype)
    scale = 0.02
    if init == "normal_out":
        scale = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def param_specs(cfg: ArchConfig):
    """Sharding-spec pytree matching init_params, without any allocation."""
    shapes = _block_shapes(cfg)
    specs = {"blocks": {n: P(S_AX, *spec) for n, (_, spec, _) in
                        sorted(shapes.items())},
             "final_norm": P(None)}
    if cfg.norm_style == "ln_pre":
        specs["final_norm_b"] = P(None)
    if cfg.frontend == "none":
        specs["embed"] = P((S_AX, T_AX), None)
    if not cfg.tie_embeddings:
        specs["head"] = P(None, (S_AX, T_AX))
    return specs


def param_shapes(cfg: ArchConfig, dtype=jnp.float32):
    """ShapeDtypeStruct pytree matching init_params (no allocation)."""
    shapes = _block_shapes(cfg)
    Lp = cfg.padded_layers
    blocks = {n: jax.ShapeDtypeStruct((Lp,) + shp, dtype)
              for n, (shp, _, _) in sorted(shapes.items())}
    out = {"blocks": blocks,
           "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), dtype)}
    if cfg.norm_style == "ln_pre":
        out["final_norm_b"] = jax.ShapeDtypeStruct((cfg.d_model,), dtype)
    Vp = padded_vocab(cfg)
    if cfg.frontend == "none":
        out["embed"] = jax.ShapeDtypeStruct((Vp, cfg.d_model), dtype)
    if not cfg.tie_embeddings:
        out["head"] = jax.ShapeDtypeStruct((cfg.d_model, Vp), dtype)
    return out


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    """Returns (params, specs) with block leaves stacked [padded_layers, ...]."""
    shapes = _block_shapes(cfg)
    Lp = cfg.padded_layers
    keys = jax.random.split(key, len(shapes) + 3)
    blocks, bspecs = {}, {}
    for i, (name, (shp, spec, init)) in enumerate(sorted(shapes.items())):
        def one(k):
            return _init_leaf(k, shp, init, cfg, dtype)
        blocks[name] = jax.vmap(one)(jax.random.split(keys[i], Lp))
        bspecs[name] = P(S_AX, *spec)
    params = {"blocks": blocks,
              "final_norm": jnp.zeros((cfg.d_model,), dtype)
              if cfg.norm_style != "ln_pre" else jnp.ones((cfg.d_model,), dtype)}
    specs = {"blocks": bspecs, "final_norm": P(None)}
    if cfg.norm_style == "ln_pre":
        params["final_norm_b"] = jnp.zeros((cfg.d_model,), dtype)
        specs["final_norm_b"] = P(None)
    Vp = padded_vocab(cfg)
    if cfg.frontend == "none":
        params["embed"] = _init_leaf(keys[-2], (Vp, cfg.d_model),
                                     "normal", cfg, dtype)
        specs["embed"] = P((S_AX, T_AX), None)
    if not cfg.tie_embeddings:
        params["head"] = _init_leaf(keys[-1], (cfg.d_model, Vp),
                                    "normal", cfg, dtype)
        specs["head"] = P(None, (S_AX, T_AX))
    return params, specs


# ----------------------------------------------------------------------------
# Embedding / loss (outside the pipeline shard_map; GSPMD-sharded)
# ----------------------------------------------------------------------------
def embed_tokens(cfg: ArchConfig, params, tokens_or_embeds):
    if cfg.frontend != "none":
        x = tokens_or_embeds           # precomputed frame/patch embeddings
    else:
        x = jnp.take(params["embed"], tokens_or_embeds, axis=0)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    return x


def final_hidden_norm(cfg: ArchConfig, params, h):
    if cfg.norm_style == "ln_pre":
        return layer_norm(h, params["final_norm"], params["final_norm_b"],
                          eps=cfg.norm_eps)
    return rms_norm(h, params["final_norm"], eps=cfg.norm_eps)


def head_matrix(cfg: ArchConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["head"]


def lm_loss(cfg: ArchConfig, params, hidden, labels, *, chunk=512):
    h = final_hidden_norm(cfg, params, hidden)
    return chunked_cross_entropy(h, head_matrix(cfg, params), labels,
                                 chunk=min(chunk, h.shape[1]),
                                 valid_vocab=cfg.vocab_size)


# ----------------------------------------------------------------------------
# Stage application (unrolled layer slots; used inside the pipeline shard_map)
# ----------------------------------------------------------------------------
def stage_apply(cfg: ArchConfig, blocks_local, x, meta_local, ctx: LayerCtx,
                cache_local=None):
    """blocks_local: leaves [slots, ...] (this stage's slice).
    meta_local: dict of [slots] arrays (kind/valid/full_i/win_i).
    Returns (x, cache_local, aux)."""
    aux = jnp.zeros((), jnp.float32)
    uk = uniform_kind(cfg)
    for s in range(cfg.layer_slots):
        p_l = jax.tree.map(lambda a: a[s], blocks_local)
        ctx_s = dc_replace(
            ctx,
            kind=uk if uk is not None else meta_local["kind"][s],
            valid=True if uk is not None else meta_local["valid"][s],
            full_i=meta_local["full_i"][s],
            win_i=meta_local["win_i"][s],
            ssm_i=s,
        )
        x, cache_local, a = apply_layer(cfg, p_l, x, ctx_s, cache_local)
        aux = aux + a
    return x, cache_local, aux


# ----------------------------------------------------------------------------
# Caches — layout knowledge lives in repro.serve.cache; these wrappers keep
# the historical import site (`lm.cache_struct` etc.) working by delegating.
# ----------------------------------------------------------------------------
def serve_dtypes(compute_dtype: str, cache_dtype: str = ""):
    from repro.serve import cache as cache_lib
    return cache_lib.serve_dtypes(compute_dtype, cache_dtype)


def cache_struct(cfg: ArchConfig, batch: int, max_len: int, *,
                 seq_shards: int = 1, dtype=jnp.bfloat16):
    from repro.serve import cache as cache_lib
    return cache_lib.cache_struct(cfg, batch, max_len, seq_shards=seq_shards,
                                  dtype=dtype)


def init_cache(cfg: ArchConfig, batch: int, max_len: int, *, seq_shards=1,
               dtype=jnp.bfloat16):
    from repro.serve import cache as cache_lib
    return cache_lib.init_cache(cfg, batch, max_len, seq_shards=seq_shards,
                                dtype=dtype)


# ----------------------------------------------------------------------------
# Reference (non-pipelined, single-device) forward — the pipeline oracle
# ----------------------------------------------------------------------------
def forward_ref(cfg: ArchConfig, params, tokens_or_embeds, *, mode="train",
                cache=None, pos=None, labels=None, lens=None,
                kernel_backend="ref"):
    """Plain layer loop. Returns (loss or hidden, cache, aux). `lens` [B]
    marks per-row prompt lengths for variable-length (right-padded)
    prefill — cache writes stop at each row's length. `kernel_backend`
    ("ref"/"interpret"/"tpu") picks the jnp paths or the Pallas kernels for
    the attention/SSM mixes."""
    x = embed_tokens(cfg, params, tokens_or_embeds)
    meta = layer_meta(cfg)
    aux_t = jnp.zeros((), jnp.float32)
    Lp = cfg.padded_layers
    kinds = meta["kind"].reshape(-1)
    valid = meta["valid"].reshape(-1)
    full_i = meta["full_i"].reshape(-1)
    win_i = meta["win_i"].reshape(-1)
    sl = cfg.layer_slots
    for l in range(Lp):
        if not valid[l]:
            continue
        st_idx = l // sl
        # reference runs with global cache (stage-major group indexing)
        ctx = LayerCtx(mode=mode, pos=pos, kind=int(kinds[l]),
                       full_i=int(st_idx * meta["m_full"] + full_i[l]),
                       win_i=int(st_idx * meta["m_win"] + win_i[l]),
                       ssm_i=l, valid=True, lens=lens,
                       kernel_backend=kernel_backend)
        p_l = jax.tree.map(lambda a: a[l], params["blocks"])
        x, cache, a = apply_layer(cfg, p_l, x, ctx, cache)
        aux_t = aux_t + a
    if mode == "train" and labels is not None:
        return lm_loss(cfg, params, x, labels) + 0.01 * aux_t / max(
            cfg.num_layers, 1), cache, aux_t
    return x, cache, aux_t


def logits_ref(cfg: ArchConfig, params, hidden):
    h = final_hidden_norm(cfg, params, hidden)
    logits = h.astype(jnp.float32) @ head_matrix(cfg, params).astype(
        jnp.float32)
    return logits[..., : cfg.vocab_size]


# ----------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ----------------------------------------------------------------------------
def input_specs(run: RunConfig) -> dict[str, Any]:
    """Model inputs for the jitted step of this (arch, shape) cell."""
    cfg, shp = run.arch, run.shape
    B, S = shp.global_batch, shp.seq_len
    stub = cfg.frontend != "none"
    dt, cache_dt = serve_dtypes(run.compute_dtype, run.cache_dtype)
    if shp.kind == "train":
        inp = (jax.ShapeDtypeStruct((B, S, cfg.d_model), dt) if stub
               else jax.ShapeDtypeStruct((B, S), jnp.int32))
        return {"inputs": inp,
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if shp.kind == "prefill":
        inp = (jax.ShapeDtypeStruct((B, S, cfg.d_model), dt) if stub
               else jax.ShapeDtypeStruct((B, S), jnp.int32))
        cache, _ = cache_struct(cfg, B, S, dtype=cache_dt)
        return {"inputs": inp, "cache": cache}
    # decode: one token against a cache of seq_len
    seq_shards = 16 if B < 16 else 1
    inp = (jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt) if stub
           else jax.ShapeDtypeStruct((B, 1), jnp.int32))
    cache, _ = cache_struct(cfg, B, S, seq_shards=seq_shards, dtype=cache_dt)
    return {"inputs": inp, "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
