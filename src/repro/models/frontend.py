"""Modality frontend stubs for [audio] / [vlm] architectures.

Per the assignment, these architectures specify the transformer BACKBONE only;
the EnCodec / VQ-VAE frontends are stubs that produce precomputed frame/patch
embeddings. For runnable examples we synthesize embeddings deterministically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def stub_embeddings(cfg: ArchConfig, key, batch: int, seq_len: int,
                    dtype=jnp.float32):
    """Deterministic stand-in for frontend output: [B, S, d_model]."""
    return 0.02 * jax.random.normal(key, (batch, seq_len, cfg.d_model), dtype)


def stub_labels(cfg: ArchConfig, key, batch: int, seq_len: int):
    return jax.random.randint(key, (batch, seq_len), 0, cfg.vocab_size,
                              dtype=jnp.int32)
