"""Model zoo: layer-sequential LMs covering all assigned architecture families."""
