"""Per-layer block application for every assigned architecture family.

A "layer" is applied with a uniform signature so pipeline stages can unroll
their layer slots under SPMD (all stages execute the same program; per-layer
behaviour — attention kind, cache group slot — is data, not structure).

Conventions:
  x [B, S, d]; params p are the per-layer leaves (no layer dim, local tp shard)
  kind: 0 = full attention, 1 = windowed, (ssm archs: ignored)
  cache: dict of stage-local cache groups (see lm.init_cache)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kernel_ops
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import rms_norm, layer_norm, rope_cos_sin, apply_rope
from repro.serve import cache as cache_lib


@dataclass
class LayerCtx:
    mode: str                       # train | prefill | decode
    pos: Any = None                 # decode position (traced scalar)
    q_offset: int = 0
    tp_axis: Optional[str] = None   # mesh axis for TP reductions
    merge_axis: Optional[str] = None  # seq-sharded KV merge axis (long decode)
    seq_offset: Any = 0             # this shard's first cache slot position
    kind: Any = 0                   # 0 full / 1 windowed (python or traced int)
    full_i: Any = 0                 # slot in the stage-local full-KV group
    win_i: Any = 0                  # slot in the stage-local windowed group
    ssm_i: Any = 0                  # slot in the stage-local ssm group
    valid: Any = True               # padded layer slots are masked out
    lens: Any = None                # per-row prompt lengths ([B]) — prefill
                                    # of variable-length (right-padded)
                                    # prompts; None = every row is full
    kernel_backend: str = "ref"     # "ref" = jnp paths; "interpret"/"tpu"
                                    # route the full-attention prefill/decode
                                    # and chunked SSM mixes through the
                                    # repro.kernels Pallas kernels


def _psum(x, axis):
    return jax.lax.psum(x, axis) if axis is not None else x


def _norm(cfg: ArchConfig, p, key, x):
    if cfg.norm_style == "ln_pre":
        return layer_norm(x, p[key], p[key + "_b"], eps=cfg.norm_eps)
    return rms_norm(x, p[key], eps=cfg.norm_eps)


def _mlp_dense(cfg: ArchConfig, p, x):
    """wi [d, G, ffl], wo [ffl, d]. Returns the pre-psum partial."""
    h = jnp.einsum("bsd,dgf->bsgf", x, p["mlp_wi"].astype(x.dtype))
    if cfg.mlp_type == "swiglu":
        a = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    elif cfg.mlp_type == "geglu":
        a = jax.nn.gelu(h[..., 0, :], approximate=True) * h[..., 1, :]
    elif cfg.mlp_type == "gelu":
        a = jax.nn.gelu(h[..., 0, :], approximate=True)
    else:  # relu2
        r = jax.nn.relu(h[..., 0, :])
        a = r * r
    return a @ p["mlp_wo"].astype(x.dtype)


def _mlp_moe(cfg: ArchConfig, p, x, tp_axis):
    from repro.models.moe import moe_mlp
    moe_p = {"router": p["router"], "w_in": p["moe_w_in"],
             "w_out": p["moe_w_out"]}
    out, aux = moe_mlp(
        moe_p, x, num_experts=cfg.num_experts, top_k=cfg.top_k,
        mlp_type=cfg.mlp_type, capacity_factor=cfg.capacity_factor)
    return _psum(out, tp_axis), aux


# ----------------------------------------------------------------------------
# Attention mix (dense / moe / hybrid attention branch)
# ----------------------------------------------------------------------------
def _qkv(cfg: ArchConfig, p, xn, ctx: LayerCtx):
    B, S, _ = xn.shape
    hd = cfg.head_dim
    q = (xn @ p["wq"].astype(xn.dtype)).reshape(B, S, -1, hd)
    k = (xn @ p["wk"].astype(xn.dtype)).reshape(B, S, -1, hd)
    v = (xn @ p["wv"].astype(xn.dtype)).reshape(B, S, -1, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], eps=cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], eps=cfg.norm_eps)
    # rope (theta may differ for global layers, e.g. gemma3)
    tl = cfg.rope_theta
    tg = cfg.rope_theta_global or cfg.rope_theta
    if isinstance(ctx.kind, int):
        theta = tg if ctx.kind == 0 else tl
    else:
        theta = jnp.where(ctx.kind == 0, tg, tl)
    if ctx.mode == "decode":
        p_ = jnp.asarray(ctx.pos)
        # scalar pos -> [1] (broadcast over batch); per-row pos [B] -> [B, 1]
        positions = p_[None] if p_.ndim == 0 else p_[:, None]
    else:
        positions = ctx.q_offset + jnp.arange(S)
    cos, sin = rope_cos_sin(positions, hd, theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _attn_train(cfg: ArchConfig, p, xn, ctx: LayerCtx, cache):
    """Train/prefill attention; writes cache in prefill. Pre-psum partial out."""
    q, k, v = _qkv(cfg, p, xn, ctx)
    B, S, Hl, hd = q.shape

    def full_path():
        if ctx.kernel_backend != "ref":
            return kernel_ops.attention(q, k, v, causal=True, window=0,
                                        backend=ctx.kernel_backend)
        return attn_lib.flash_attention(q, k, v, causal=True, window=0)

    def win_path():
        return attn_lib.banded_attention(q, k, v, window=cfg.window_size)

    if isinstance(ctx.kind, int):
        o = full_path() if ctx.kind == 0 else win_path()
    else:
        o = jax.lax.cond(ctx.kind == 0, full_path, win_path)

    new_cache = cache
    if ctx.mode == "prefill" and cache is not None:
        new_cache = dict(cache)
        if "kv_full" in cache:
            kf, vf = cache["kv_full"]
            i = jnp.asarray(ctx.full_i)
            sel = jnp.asarray(ctx.kind == 0)
            if cache_lib.is_paged(cache):
                tab = cache["block_tab"]
                sel_b = jnp.broadcast_to(sel & jnp.asarray(ctx.valid),
                                         (B,))
                kf = cache_lib.page_write_prompt(kf, i, tab, k, sel_b,
                                                 ctx.lens)
                vf = cache_lib.page_write_prompt(vf, i, tab, v, sel_b,
                                                 ctx.lens)
            else:
                Sc = kf.shape[2]
                ks = k[:, -Sc:] if S >= Sc else jnp.pad(k, ((0, 0), (0, Sc - S), (0, 0), (0, 0)))
                vs = v[:, -Sc:] if S >= Sc else jnp.pad(v, ((0, 0), (0, Sc - S), (0, 0), (0, 0)))
                kf = kf.at[i].set(jnp.where(sel, ks.astype(kf.dtype), kf[i]))
                vf = vf.at[i].set(jnp.where(sel, vs.astype(vf.dtype), vf[i]))
            new_cache["kv_full"] = (kf, vf)
        if "kv_win" in cache:
            kw, vw = cache["kv_win"]
            W = kw.shape[2]
            # ring layout: slot = position % W
            sel = jnp.asarray(ctx.kind == 1)
            i = jnp.asarray(ctx.win_i)
            if ctx.lens is None:
                take = min(W, S)
                kl, vl = k[:, -take:], v[:, -take:]
                pos_tail = ctx.q_offset + S - take + jnp.arange(take)
                slots = pos_tail % W
                kw_i = kw[i].at[:, slots].set(kl.astype(kw.dtype))
                vw_i = vw[i].at[:, slots].set(vl.astype(vw.dtype))
            else:
                # variable-length rows: walk the prompt in W-sized chunks so
                # each write's ring slots are unique; positions >= lens[b]
                # keep the slot's previous value, so every row's ring ends
                # up holding exactly its own last min(W, lens[b]) tokens
                kw_i, vw_i = kw[i], vw[i]
                for c0 in range(0, S, W):
                    take = min(W, S - c0)
                    gpos = ctx.q_offset + c0 + jnp.arange(take)
                    slots = gpos % W
                    live = gpos[None, :] < ctx.lens[:, None]   # [B, take]
                    k_c = jnp.where(live[..., None, None],
                                    k[:, c0:c0 + take].astype(kw.dtype),
                                    kw_i[:, slots])
                    v_c = jnp.where(live[..., None, None],
                                    v[:, c0:c0 + take].astype(vw.dtype),
                                    vw_i[:, slots])
                    kw_i = kw_i.at[:, slots].set(k_c)
                    vw_i = vw_i.at[:, slots].set(v_c)
            kw = kw.at[i].set(jnp.where(sel, kw_i, kw[i]))
            vw = vw.at[i].set(jnp.where(sel, vw_i, vw[i]))
            new_cache["kv_win"] = (kw, vw)
    return o.reshape(B, S, Hl * hd) @ p["wo"].astype(xn.dtype), new_cache


def _attn_decode(cfg: ArchConfig, p, xn, ctx: LayerCtx, cache):
    """Single-token attention against the stage-local cache groups. ctx.pos
    is a scalar (aligned batch) or a [B] vector (continuous batching: each
    row at its own depth). Full-attention K/V is read through the block
    table (paged trees) or directly (the contiguous reference layout)."""
    q, k, v = _qkv(cfg, p, xn, ctx)
    B, _, Hl, hd = q.shape
    pos_a = jnp.asarray(ctx.pos)
    per_row = pos_a.ndim == 1
    new_cache = dict(cache)
    outs = []

    if "kv_full" in cache and cache_lib.is_paged(cache):
        kf, vf = cache["kv_full"]
        i = jnp.asarray(ctx.full_i)
        tab = cache["block_tab"]
        cap = tab.shape[1] * kf.shape[2]                # pps * page_size
        pos_b = jnp.broadcast_to(pos_a, (B,))
        sel = jnp.asarray(ctx.kind == 0) & jnp.asarray(ctx.valid)
        sel_b = jnp.broadcast_to(sel, (B,)) & (pos_b >= 0) & (pos_b < cap)
        kf = cache_lib.page_write_token(kf, i, tab, pos_b, k, sel_b)
        vf = cache_lib.page_write_token(vf, i, tab, pos_b, v, sel_b)
        new_cache["kv_full"] = (kf, vf)
        if ctx.kernel_backend != "ref":
            # fused walk: the kernel indexes the pool through the block
            # table with per-row lengths — no page_view materialization
            lens_row = jnp.clip(pos_b + 1, 0, cap)
            o_full = kernel_ops.decode_attention_paged(
                q[:, 0], kf, vf, tab, lens_row, layer=i,
                backend=ctx.kernel_backend)[:, None]
        else:
            k_view, gpos = cache_lib.page_view(kf, i, tab)
            v_view, _ = cache_lib.page_view(vf, i, tab)
            o_full = attn_lib.decode_attend(q, k_view, v_view, gpos, ctx.pos,
                                            window=0, merge_axis=None)
        outs.append((0, o_full))
    elif "kv_full" in cache:
        kf, vf = cache["kv_full"]
        i = jnp.asarray(ctx.full_i)
        Sc = kf.shape[2]
        li = pos_a - ctx.seq_offset                     # scalar or [B]
        in_rng = (li >= 0) & (li < Sc)
        lic = jnp.clip(li, 0, Sc - 1)
        sel = jnp.asarray(ctx.kind == 0) & in_rng & jnp.asarray(ctx.valid)
        if per_row:
            kf = cache_lib.upd_kv_rows(kf, i, lic, k, sel)
            vf = cache_lib.upd_kv_rows(vf, i, lic, v, sel)
        else:
            kf = cache_lib.upd_kv(kf, i, lic, k, sel)
            vf = cache_lib.upd_kv(vf, i, lic, v, sel)
        new_cache["kv_full"] = (kf, vf)
        if ctx.kernel_backend != "ref" and ctx.merge_axis is None:
            # per-row live lengths; rows outside this shard's range clip
            # to an empty (zero-output) window, matching the sel mask
            lens_row = jnp.clip(jnp.broadcast_to(pos_a, (B,)) + 1
                                - ctx.seq_offset, 0, Sc)
            o_full = kernel_ops.decode_attention(
                q[:, 0], kf[i].transpose(0, 2, 1, 3),
                vf[i].transpose(0, 2, 1, 3), lens_row, window=0,
                backend=ctx.kernel_backend)[:, None]
        else:
            gpos = ctx.seq_offset + jnp.arange(Sc)
            o_full = attn_lib.decode_attend(q, kf[i], vf[i], gpos, ctx.pos,
                                            window=0,
                                            merge_axis=ctx.merge_axis)
        outs.append((0, o_full))

    if "kv_win" in cache:
        kw, vw = cache["kv_win"]
        i = jnp.asarray(ctx.win_i)
        W = kw.shape[2]
        slot = pos_a % W                                # scalar or [B]
        sel = jnp.asarray(ctx.kind == 1) & jnp.asarray(ctx.valid)
        if per_row:
            kw = cache_lib.upd_kv_rows(kw, i, slot, k,
                                       jnp.broadcast_to(sel, (B,)))
            vw = cache_lib.upd_kv_rows(vw, i, slot, v,
                                       jnp.broadcast_to(sel, (B,)))
            # ring slot j holds position pos_b - ((pos_b - j) % W), per row
            gpos = pos_a[:, None] - ((pos_a[:, None] - jnp.arange(W)) % W)
        else:
            kw = cache_lib.upd_kv(kw, i, slot, k, sel)
            vw = cache_lib.upd_kv(vw, i, slot, v, sel)
            gpos = ctx.pos - ((ctx.pos - jnp.arange(W)) % W)
        new_cache["kv_win"] = (kw, vw)
        o_win = attn_lib.decode_attend(q, kw[i], vw[i], gpos, ctx.pos,
                                       window=W + 1, merge_axis=None)
        outs.append((1, o_win))

    if len(outs) == 1:
        o = outs[0][1]
    else:
        o = jnp.where(jnp.asarray(ctx.kind == 0), outs[0][1], outs[1][1])
    return o.reshape(B, 1, Hl * hd) @ p["wo"].astype(xn.dtype), new_cache


# ----------------------------------------------------------------------------
# SSM branches
# ----------------------------------------------------------------------------
def _ssd_branch(cfg: ArchConfig, p, xn, ctx: LayerCtx, cache):
    H, N, di = cfg.n_ssm_heads, cfg.ssm_state, cfg.d_inner
    new_cache = dict(cache) if cache is not None else None
    if ctx.mode == "decode":
        i = jnp.asarray(ctx.ssm_i)
        st, tail = cache["ssm_state"][i], cache["conv_tail"][i]
        y, st2, tail2 = ssm_lib.ssd_mix_step(
            p, xn, st, tail, heads=H, d_state=N, d_inner=di)
        sel = jnp.asarray(ctx.valid)
        new_cache["ssm_state"] = cache["ssm_state"].at[i].set(
            jnp.where(sel, st2, st))
        new_cache["conv_tail"] = cache["conv_tail"].at[i].set(
            jnp.where(sel, tail2.astype(cache["conv_tail"].dtype), tail))
        return y, new_cache
    y, stT, tail = ssm_lib.ssd_mix(p, xn, heads=H, d_state=N, d_inner=di,
                                   lens=ctx.lens if ctx.mode == "prefill"
                                   else None,
                                   kernel_backend=ctx.kernel_backend)
    if ctx.mode == "prefill" and cache is not None:
        i = jnp.asarray(ctx.ssm_i)
        sel = jnp.asarray(ctx.valid)
        new_cache["ssm_state"] = cache["ssm_state"].at[i].set(
            jnp.where(sel, stT, cache["ssm_state"][i]))
        new_cache["conv_tail"] = cache["conv_tail"].at[i].set(
            jnp.where(sel, tail.astype(cache["conv_tail"].dtype),
                      cache["conv_tail"][i]))
    return y, new_cache


def _rwkv_layer(cfg: ArchConfig, p, x, ctx: LayerCtx, cache):
    """Full RWKV6 layer: ln1 + time-mix, ln2 + channel-mix."""
    H = cfg.n_ssm_heads
    xx1 = rms_norm(x, p["ln1"], eps=cfg.norm_eps)
    new_cache = dict(cache) if cache is not None else None
    if ctx.mode == "decode":
        i = jnp.asarray(ctx.ssm_i)
        st = cache["ssm_state"][i]
        shifts = cache["shift"][i]                       # [B, 2, d]
        y, st2, last1 = ssm_lib.rwkv6_mix_step(
            p, xx1, st, shifts[:, 0:1], heads=H)
    else:
        y, st2, last1 = ssm_lib.rwkv6_mix(
            p, xx1, heads=H,
            lens=ctx.lens if ctx.mode == "prefill" else None,
            kernel_backend=ctx.kernel_backend)
    x = x + y
    xx2 = rms_norm(x, p["ln2"], eps=cfg.norm_eps)
    if ctx.mode == "decode":
        prev2 = shifts[:, 1:2]
    else:
        prev2 = None
    xp = ssm_lib._shift(xx2, prev2)
    mk = xx2 + p["cm_mu_k"] * (xp - xx2)
    mr = xx2 + p["cm_mu_r"] * (xp - xx2)
    kk = jax.nn.relu(mk @ p["cm_k"].astype(x.dtype))
    cm = (kk * kk) @ p["cm_v"].astype(x.dtype)
    x = x + jax.nn.sigmoid(mr @ p["cm_r"].astype(x.dtype)) * cm
    if cache is not None:
        i = jnp.asarray(ctx.ssm_i)
        sel = jnp.asarray(ctx.valid)
        if ctx.mode == "prefill" and ctx.lens is not None:
            # channel-mix shift state: the last *real* token per row
            last2 = jnp.take_along_axis(
                xx2, jnp.maximum(ctx.lens - 1, 0)[:, None, None], axis=1)
        else:
            last2 = xx2[:, -1:]
        new_shift = jnp.concatenate([last1, last2], axis=1)
        new_cache["ssm_state"] = cache["ssm_state"].at[i].set(
            jnp.where(sel, st2, cache["ssm_state"][i]))
        new_cache["shift"] = cache["shift"].at[i].set(
            jnp.where(sel, new_shift.astype(cache["shift"].dtype),
                      cache["shift"][i]))
    return x, new_cache


# ----------------------------------------------------------------------------
# Unified layer entry
# ----------------------------------------------------------------------------
def apply_layer(cfg: ArchConfig, p, x, ctx: LayerCtx, cache=None):
    """Returns (x_out, new_cache, aux_loss). Padded slots: x passes through."""
    aux = jnp.zeros((), jnp.float32)
    x_in = x

    if cfg.ssm_type == "rwkv6":
        x, cache = _rwkv_layer(cfg, p, x, ctx, cache)
    else:
        xn = _norm(cfg, p, "ln1", x)
        if ctx.mode == "decode":
            att, cache = _attn_decode(cfg, p, xn, ctx, cache)
        else:
            att, cache = _attn_train(cfg, p, xn, ctx, cache)
        att = _psum(att, ctx.tp_axis)
        if cfg.hybrid_parallel:
            sy, cache = _ssd_branch(cfg, {k[4:]: v for k, v in p.items()
                                          if k.startswith("ssd_")}, xn, ctx,
                                    cache)
            att = 0.5 * (rms_norm(att, p["bn_attn"], eps=cfg.norm_eps)
                         + rms_norm(sy, p["bn_ssm"], eps=cfg.norm_eps))
        if cfg.norm_style == "rms_sandwich":
            att = rms_norm(att, p["ln1_post"], eps=cfg.norm_eps)
        x = x + att
        xn2 = _norm(cfg, p, "ln2", x)
        if cfg.num_experts:
            m, aux = _mlp_moe(cfg, p, xn2, ctx.tp_axis)
        else:
            m = _psum(_mlp_dense(cfg, p, xn2), ctx.tp_axis)
        if cfg.norm_style == "rms_sandwich":
            m = rms_norm(m, p["ln2_post"], eps=cfg.norm_eps)
        x = x + m

    valid = jnp.asarray(ctx.valid)
    x = jnp.where(valid, x, x_in)
    aux = jnp.where(valid, aux, 0.0)
    return x, cache, aux
