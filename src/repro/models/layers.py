"""Primitive layers: norms, rope, MLPs, losses. Pure functions over dict params."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias
    return out.astype(dt)


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions [...]; returns cos/sin [..., head_dim//2] fp32."""
    ang = positions.astype(jnp.float32)[..., None] * rope_freqs(head_dim, theta)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, hd]; cos/sin [S, hd//2] (broadcast over batch/heads)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)
    return out.astype(dt)


# ----------------------------------------------------------------------------
# MLPs (all return the pre-output-projection activation given fused wi)
# ----------------------------------------------------------------------------
def mlp_act(h, mlp_type: str, d_ff: int):
    """h = x @ wi where wi fuses [gate; up] for gated types."""
    if mlp_type == "swiglu":
        g, u = jnp.split(h, 2, axis=-1)
        return jax.nn.silu(g) * u
    if mlp_type == "geglu":
        g, u = jnp.split(h, 2, axis=-1)
        return jax.nn.gelu(g, approximate=True) * u
    if mlp_type == "gelu":
        return jax.nn.gelu(h, approximate=True)
    if mlp_type == "relu2":
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(mlp_type)


def mlp_fused_width(mlp_type: str, d_ff: int) -> int:
    return (2 if mlp_type in ("swiglu", "geglu") else 1) * d_ff


# ----------------------------------------------------------------------------
# Loss: seq-chunked cross entropy against a (possibly tp-sharded) vocab head.
# ----------------------------------------------------------------------------
def chunked_cross_entropy(hidden, head, labels, *, chunk: int = 512,
                          logits_scale: float = 1.0,
                          valid_vocab: int | None = None):
    """Mean CE over tokens; logits never materialized beyond [B, chunk, V].

    hidden [B, S, d] - head [d, V] - labels [B, S] int32. Backward recomputes
    per chunk (jax.checkpoint), keeping the dominant temp at chunk granularity.
    """
    B, S, d = hidden.shape
    n_chunks = S // chunk if S % chunk == 0 else -1
    if n_chunks == -1:  # fall back to single chunk
        n_chunks, chunk = 1, S

    hc = hidden.reshape(B, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def one(h, l):
        # bf16 operands, f32 accumulation: halves CE weight/logit traffic
        logits = jax.lax.dot_general(
            h.astype(jnp.bfloat16), head.astype(jnp.bfloat16),
            (((h.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * logits_scale
        if valid_vocab is not None and valid_vocab < head.shape[-1]:
            col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                           logits.ndim - 1)
            logits = jnp.where(col < valid_vocab, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    def body(acc, xs):
        h, l = xs
        return acc + one(h, l), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (B * S)


def init_dense(key, shape, scale=0.02, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape)).astype(dtype)
