"""Attention: memory-efficient (flash-style) jnp implementations.

These are the reference/dry-run paths; `repro.kernels` holds the Pallas TPU
kernels with identical math. All softmax accumulation is fp32.

Layouts: q [B, S, H, hd]; k, v [B, S, KV, hd]; GQA group G = H // KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _pick_block(s: int, want: int) -> int:
    b = min(want, s)
    while s % b:
        b -= 1
    return b


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_block: int = 256, kv_block: int = 512,
                    q_offset: int = 0):
    """Online-softmax attention, tiled over q and kv blocks.

    window > 0 restricts to keys with (qpos - kpos) < window (sliding window).
    q_offset: global position of q[0] (for prefill continuation; kv starts at 0).
    NOTE (roofline): masked causal blocks are still computed in this jnp path
    (~2x attention FLOPs); the Pallas kernel skips them on TPU.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qb = _pick_block(Sq, q_block)
    kb = _pick_block(Sk, kv_block)
    nq, nk = Sq // qb, Sk // kb
    scale = hd ** -0.5

    qr = q.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)  # [nq,B,KV,G,qb,hd]
    kr = k.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)        # [nk,B,KV,kb,hd]
    vr = v.reshape(B, nk, kb, KV, hd).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_blk):
        qi, q_i = qi_blk
        gq = q_offset + qi * qb + jnp.arange(qb)                      # [qb]

        def kv_step(carry, kj_blk):
            m, l, acc = carry
            kj, k_j, v_j = kj_blk
            gk = kj * kb + jnp.arange(kb)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= gq[:, None] >= gk[None, :]
            if window > 0:
                mask &= (gq[:, None] - gk[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p, v_j.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qb, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kr, vr))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    # outs [nq, B, KV, G, qb, hd] -> [B, Sq, H, hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def banded_attention(q, k, v, *, window: int, q_block: int = 256,
                     q_offset: int = 0):
    """Sliding-window attention with FLOPs proportional to S * (window + qb).

    For each q block, gathers the contiguous kv band [start, start + window + qb)
    via dynamic_slice instead of masking the full sequence.
    """
    B, Sq, H, hd = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    qb = _pick_block(Sq, q_block)
    nq = Sq // qb
    band = window + qb
    if band >= Sk:
        return flash_attention(q, k, v, causal=True, window=window,
                               q_block=q_block, q_offset=q_offset)
    scale = hd ** -0.5
    qr = q.reshape(B, nq, qb, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    kt = k.transpose(0, 2, 1, 3)   # [B, KV, Sk, hd]
    vt = v.transpose(0, 2, 1, 3)

    def q_step(_, qi_blk):
        qi, q_i = qi_blk
        q0 = q_offset + qi * qb
        start = jnp.clip(q0 + qb - band, 0, Sk - band)
        k_b = jax.lax.dynamic_slice_in_dim(kt, start, band, axis=2)
        v_b = jax.lax.dynamic_slice_in_dim(vt, start, band, axis=2)
        gq = q0 + jnp.arange(qb)
        gk = start + jnp.arange(band)
        s = jnp.einsum("bkgqd,bkcd->bkgqc", q_i.astype(jnp.float32),
                       k_b.astype(jnp.float32)) * scale
        mask = (gq[:, None] >= gk[None, :]) & ((gq[:, None] - gk[None, :]) < window)
        s = jnp.where(mask, s, NEG_INF)
        m = jnp.max(s, axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bkgqc,bkcd->bkgqd", p, v_b.astype(jnp.float32))
        out = out / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qr))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def decode_attend(q1, k_cache, v_cache, gpos, pos, *, window: int = 0,
                  merge_axis: str | None = None):
    """Single-token attention against a (possibly sequence-sharded) KV cache.

    q1 [B, 1, H, hd] (already roped at `pos`); k_cache/v_cache [B, Sc, KV, hd]
    (the local shard); gpos [Sc] (or per-row [B, Sc]) global positions of the
    cached slots; pos the current global position — a scalar, or a [B] vector
    when batch rows decode at different depths (continuous batching).
    merge_axis: mesh axis name for flash-decoding style logsumexp merge
    across sequence shards.
    """
    B, _, H, hd = q1.shape
    _, Sc, KV, _ = k_cache.shape
    G = H // KV
    scale = hd ** -0.5
    qr = q1.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bckd->bkgc", qr, k_cache.astype(jnp.float32)) * scale
    pos_b = jnp.asarray(pos)
    pos_b = pos_b[None] if pos_b.ndim == 0 else pos_b           # [1] or [B]
    gpos_b = jnp.asarray(gpos)
    gpos_b = gpos_b[None] if gpos_b.ndim == 1 else gpos_b       # [1|B, Sc]
    valid = (gpos_b <= pos_b[:, None]) & (gpos_b >= 0)
    if window > 0:
        valid &= (pos_b[:, None] - gpos_b) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgc,bckd->bkgd", p, v_cache.astype(jnp.float32))
    if merge_axis is not None:
        m_g = jax.lax.pmax(m_safe, merge_axis)
        corr = jnp.exp(m_safe - m_g)
        l = jax.lax.psum(l * corr, merge_axis)
        o = jax.lax.psum(o * corr[..., None], merge_axis)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, 1, H, hd).astype(q1.dtype)
