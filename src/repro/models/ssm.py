"""SSM mixers: RWKV6 (Finch, data-dependent decay) and Mamba-2 style SSD.

Both are implemented in a chunked, matmul-dominant form (MXU-friendly; the
Pallas kernels in repro.kernels mirror the same math) plus a single-token
recurrent step for decoding. fp32 state/accumulation throughout.

RWKV6 per head (state S in R^{K x V}):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
with per-channel data-dependent decay w_t = exp(-exp(clip(w0 + lora(x)))).

SSD per head (state h in R^{N x P}, scalar per-head decay):
    h_t = exp(a * dt_t) h_{t-1} + dt_t B_t x_t^T
    y_t = h_t^T C_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm

# decay-rate clamp keeping exp(-cs) representable for chunk <= 16 (see DESIGN.md)
_LOGW_CLIP = (-8.0, 1.386)  # max per-step rate e^1.386 = 4.0


def rwkv6_decay(x, w0, wa, wb):
    """Per-channel log-decay (<= 0): -exp(clip(w0 + tanh(x wa) wb))."""
    lora = jnp.tanh(x.astype(jnp.float32) @ wa.astype(jnp.float32))
    raw = w0.astype(jnp.float32) + lora @ wb.astype(jnp.float32)
    return -jnp.exp(jnp.clip(raw, *_LOGW_CLIP))


def _shift(x, prev):
    """Token shift: returns x_{t-1} with prev (or zeros) for t=0. x [B,S,d]."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_projections(p, xx, prev_xx, heads):
    """Token-shifted projections. xx [B,S,d] (post-ln). Returns r,k,v,g,logw,u."""
    B, S, d = xx.shape
    hd = d // heads
    xp = _shift(xx, prev_xx)

    def mix(mu):
        return xx + mu * (xp - xx)

    r = (mix(p["mu_r"]) @ p["wr"]).reshape(B, S, heads, hd)
    k = (mix(p["mu_k"]) @ p["wk"]).reshape(B, S, heads, hd)
    v = (mix(p["mu_v"]) @ p["wv"]).reshape(B, S, heads, hd)
    g = jax.nn.silu(mix(p["mu_g"]) @ p["wg"])
    logw = rwkv6_decay(mix(p["mu_w"]), p["w0"], p["wa"], p["wb"])
    logw = logw.reshape(B, S, heads, hd)
    return r, k, v, g, logw


def _rwkv_head_out(p, y, g, heads):
    """Per-head group norm, gating and output projection. y [B,S,H,hd] fp32."""
    B, S, H, hd = y.shape
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    yn = yn.reshape(B, S, H * hd) * p["gn_scale"] + p["gn_bias"]
    out = (yn * g).astype(p["wo"].dtype) @ p["wo"]
    return out


def rwkv6_mix(p, xx, *, heads: int, chunk: int = 16, state0=None,
              prev_xx=None, lens=None, kernel_backend="ref"):
    """Chunked RWKV6 time-mix. xx [B,S,d]. Returns y, final_state, last_xx.

    lens [B] (optional): per-row valid prefix for right-padded variable-
    length prompts. Padded positions are made a state no-op (k = 0,
    decay = 1, so S_t = S_{t-1}) and last_xx is the last *real* token per
    row; y at padded positions is garbage and must not be read.

    kernel_backend != "ref" routes the inner chunked recurrence through the
    Pallas rwkv6_chunked kernel (fresh-state prefill/train only — a warm
    state0 falls back to the jnp scan)."""
    B, S, d = xx.shape
    hd = d // heads
    r, k, v, g, logw = rwkv6_projections(p, xx, prev_xx, heads)
    if lens is not None:
        live = (jnp.arange(S)[None, :] < lens[:, None])[..., None, None]
        k = jnp.where(live, k, 0.0)
        logw = jnp.where(live, logw, 0.0)
    u = p["u"].astype(jnp.float32)                          # [H, hd]
    if kernel_backend != "ref" and state0 is None:
        from repro.kernels import ops as kernel_ops
        tk = lambda a: a.astype(jnp.float32).transpose(0, 2, 1, 3)
        y4, stateT = kernel_ops.rwkv6(tk(r), tk(k), tk(v), tk(logw), u,
                                      backend=kernel_backend)
        y = y4.transpose(0, 2, 1, 3)                        # [B,S,H,hd]
        out = _rwkv_head_out(p, y.astype(jnp.float32), g, heads)
        last = xx[:, -1:] if lens is None else jnp.take_along_axis(
            xx, jnp.maximum(lens - 1, 0)[:, None, None], axis=1)
        return out.astype(xx.dtype), stateT, last
    if state0 is None:
        state0 = jnp.zeros((B, heads, hd, hd), jnp.float32)

    C = min(chunk, S)
    while S % C:
        C -= 1
    n = S // C

    def chz(a):   # [B,S,H,x] -> [n,B,C,H,x]
        return a.reshape(B, n, C, *a.shape[2:]).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = map(chz, (r.astype(jnp.float32), k.astype(jnp.float32),
                               v.astype(jnp.float32), logw))

    tri = jnp.tril(jnp.ones((C, C), jnp.float32), k=-1)     # strict lower

    def chunk_step(S0, xs):
        r_, k_, v_, w_ = xs                                  # [B,C,H,*]
        cs = jnp.cumsum(w_, axis=1)                          # [B,C,H,K] (<=0)
        cs_prev = cs - w_                                    # cs_{t-1} (cs_0 = 0)
        r_p = r_ * jnp.exp(cs_prev)
        k_p = k_ * jnp.exp(-cs)
        scores = jnp.einsum("bthi,bshi->bhts", r_p, k_p) * tri[None, None]
        diag = jnp.einsum("bthi,hi,bthi->bth", r_, u, k_)    # u-bonus on t==s
        y = jnp.einsum("bhts,bshj->bthj", scores, v_)
        y += diag[..., None] * v_
        y += jnp.einsum("bthi,bhij->bthj", r_p, S0)          # inter-chunk
        S_new = jnp.exp(cs[:, -1])[..., None] * (
            S0 + jnp.einsum("bshi,bshj->bhij", k_p, v_))
        return S_new, y

    stateT, yc = jax.lax.scan(chunk_step, state0, (rc, kc, vc, wc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, heads, hd)
    out = _rwkv_head_out(p, y, g, heads)
    last = xx[:, -1:] if lens is None else jnp.take_along_axis(
        xx, jnp.maximum(lens - 1, 0)[:, None, None], axis=1)
    return out.astype(xx.dtype), stateT, last


def rwkv6_mix_step(p, xx, state, prev_xx, *, heads: int):
    """Single-token RWKV6 step. xx [B,1,d]; state [B,H,hd,hd] fp32."""
    B, _, d = xx.shape
    hd = d // heads
    r, k, v, g, logw = rwkv6_projections(p, xx, prev_xx, heads)
    r, k, v, w = (a[:, 0].astype(jnp.float32) for a in (r, k, v, logw))
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhi,bhj->bhij", k, v)
    y = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    state = jnp.exp(w)[..., None] * state + kv
    out = _rwkv_head_out(p, y[:, None].reshape(B, 1, heads, hd), g, heads)
    return out.astype(xx.dtype), state, xx


# ----------------------------------------------------------------------------
# SSD (Mamba-2 style), scalar-per-head decay
# ----------------------------------------------------------------------------
def _dw_conv4(x, w, tail=None, lens=None):
    """Causal depthwise conv, kernel 4, via shifts. x [B,S,c]; w [4,c];
    tail [B,3,c] previous inputs (decode continuity). lens [B] (optional):
    the returned tail holds each row's last three inputs *before* position
    lens[b] (variable-length right-padded prefill) instead of xp[:, -3:]."""
    B, S, c = x.shape
    if tail is None:
        tail = jnp.zeros((B, 3, c), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)            # [B, S+3, c]
    out = sum(xp[:, 3 - i: 3 - i + S] * w[3 - i] for i in range(4))
    if lens is None:
        return out, xp[:, -3:]
    # xp index t+3 holds input position t -> rows' last real inputs sit at
    # xp indices lens[b] .. lens[b]+2
    idx = jnp.clip(lens, 0, S)[:, None] + jnp.arange(3)[None, :]
    return out, jnp.take_along_axis(xp, idx[..., None], axis=1)


def ssd_projections(p, x, cfg_heads, d_inner, d_state, conv_tail=None,
                    lens=None):
    """in_proj + conv + activations. x [B,S,d]. Returns z,xh,Bm,Cm,dt,tail."""
    B, S, _ = x.shape
    H, N = cfg_heads, d_state
    proj = x @ p["in_proj"]
    z, xbc, dt_raw = jnp.split(
        proj, [d_inner, d_inner + d_inner + 2 * N], axis=-1)
    xbc, tail = _dw_conv4(xbc, p["conv_w"], conv_tail, lens=lens)
    xbc = jax.nn.silu(xbc)
    xh, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    return z, xh.reshape(B, S, H, d_inner // H), Bm, Cm, dt, tail


def _ssd_out(p, x, y, xh, z, d_inner):
    """Shared SSD output tail: D-skip, group norm, gating, out projection.
    y/xh [B,S,H,P]."""
    B, S = x.shape[:2]
    y = y.astype(jnp.float32) + \
        p["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y, p["norm_scale"]) * jax.nn.silu(z)
    out = y.astype(p["out_proj"].dtype) @ p["out_proj"]
    return out.astype(x.dtype)


def ssd_mix(p, x, *, heads: int, d_state: int, d_inner: int, chunk: int = 64,
            state0=None, conv_tail=None, lens=None, kernel_backend="ref"):
    """Chunked SSD. x [B,S,d]. Returns y [B,S,d], final_state, conv_tail.

    lens [B] (optional): per-row valid prefix for right-padded variable-
    length prompts. Padded positions are a state no-op (dt = 0, so
    h_t = h_{t-1}) and the returned conv tail holds each row's last three
    *real* inputs; y at padded positions is garbage and must not be read.

    kernel_backend != "ref" routes the inner chunked recurrence through the
    Pallas ssd_chunked kernel (fresh-state prefill/train only — a warm
    state0 falls back to the jnp scan)."""
    B, S, d = x.shape
    H, N, P = heads, d_state, d_inner // heads
    z, xh, Bm, Cm, dt, tail = ssd_projections(p, x, H, d_inner, N, conv_tail,
                                              lens=lens)
    if lens is not None:
        # padded positions are a state no-op: dt = 0 -> decay exp(0) = 1
        # and a zero state injection
        dt = jnp.where((jnp.arange(S)[None, :] < lens[:, None])[..., None],
                       dt, 0.0)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))        # [H], < 0
    if kernel_backend != "ref" and state0 is None:
        from repro.kernels import ops as kernel_ops
        y4, stateT = kernel_ops.ssd(
            xh.astype(jnp.float32).transpose(0, 2, 1, 3),   # [B,H,S,P]
            dt.transpose(0, 2, 1),                          # [B,H,S]
            Bm.astype(jnp.float32), Cm.astype(jnp.float32), a,
            backend=kernel_backend)
        y = y4.transpose(0, 2, 1, 3)                        # [B,S,H,P]
        return _ssd_out(p, x, y, xh, z, d_inner), stateT, tail
    if state0 is None:
        state0 = jnp.zeros((B, H, N, P), jnp.float32)

    C_ = min(chunk, S)
    while S % C_:
        C_ -= 1
    n = S // C_

    def chz(arr):
        return arr.reshape(B, n, C_, *arr.shape[2:]).transpose(
            1, 0, 2, *range(3, arr.ndim + 1))

    xc = chz(xh.astype(jnp.float32))                    # [n,B,C,H,P]
    Bc = chz(Bm.astype(jnp.float32))                    # [n,B,C,N]
    Cc = chz(Cm.astype(jnp.float32))
    dtc = chz(dt)                                       # [n,B,C,H]

    def chunk_step(h0, xs):
        x_, B_, C_m, dt_ = xs
        la = dt_ * a                                    # [B,C,H] log-decay <= 0
        cs = jnp.cumsum(la, axis=1)
        # intra-chunk: scores[t,s] = (C_t . B_s) * exp(cs_t - cs_s) * dt_s, s<=t
        cb = jnp.einsum("btn,bsn->bts", C_m, B_)
        L = jnp.exp(cs[:, :, None, :] - cs[:, None, :, :])      # [B,t,s,H]
        L = jnp.where(jnp.tril(jnp.ones((L.shape[1], L.shape[1]), bool))[
            None, :, :, None], L, 0.0)
        y = jnp.einsum("bts,btsh,bsh,bshp->bthp", cb, L, dt_, x_)
        # inter-chunk: y_t += (C_t exp(cs_t)) . h0
        y += jnp.einsum("btn,bth,bhnp->bthp", C_m, jnp.exp(cs), h0)
        # state update
        dec = jnp.exp(cs[:, -1:, :] - cs)               # [B,C,H]
        h_new = jnp.exp(cs[:, -1])[..., None, None] * h0 + jnp.einsum(
            "bsn,bsh,bshp->bhnp", B_, dec * dt_, x_)
        return h_new, y

    stateT, yc = jax.lax.scan(chunk_step, state0, (xc, Bc, Cc, dtc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return _ssd_out(p, x, y, xh, z, d_inner), stateT, tail


def ssd_mix_step(p, x, state, conv_tail, *, heads: int, d_state: int,
                 d_inner: int):
    """Single-token SSD step. x [B,1,d]."""
    B, _, d = x.shape
    H, N, P = heads, d_state, d_inner // heads
    z, xh, Bm, Cm, dt, tail = ssd_projections(p, x, H, d_inner, N, conv_tail)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    la = dt[:, 0] * a                                   # [B,H]
    x0 = xh[:, 0].astype(jnp.float32)                   # [B,H,P]
    B0 = Bm[:, 0].astype(jnp.float32)                   # [B,N]
    C0 = Cm[:, 0].astype(jnp.float32)
    state = jnp.exp(la)[..., None, None] * state + jnp.einsum(
        "bn,bh,bhp->bhnp", B0, dt[:, 0], x0)
    y = jnp.einsum("bn,bhnp->bhp", C0, state)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * x0
    y = y.reshape(B, 1, d_inner)
    y = rms_norm(y, p["norm_scale"]) * jax.nn.silu(z)
    out = y.astype(p["out_proj"].dtype) @ p["out_proj"]
    return out.astype(x.dtype), state, tail
