"""The paper's own models (VGG-19, ResNet-152) for the allocation/partition
benchmarks, plus a small runnable conv net for CPU smoke.

The benchmarks need per-layer (flops, param_bytes, act_bytes) tables for the
partitioner — derived analytically from the published architectures at
224x224 (VGG-19: 19.6 GFLOPs/image, 548 MB params; ResNet-152: 11.3 GFLOPs,
230 MB), the models the paper trains (Section 8.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

VGG19_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
             512, 512, 512, 512, "M", 512, 512, 512, 512, "M"]


def vgg19_layer_costs(batch: int = 32):
    """Per-layer (flops fwd+bwd, param_bytes, act_bytes) at 224x224."""
    h = w = 224
    cin = 3
    fl, pb, ab = [], [], []
    for v in VGG19_CFG:
        if v == "M":
            h //= 2
            w //= 2
            continue
        f = 2 * batch * h * w * cin * v * 9            # 3x3 conv
        fl.append(3.0 * f)
        pb.append(cin * v * 9 * 4.0)
        ab.append(batch * h * w * v * 4.0)
        cin = v
    # classifier: 25088->4096->4096->1000 (the bulk of VGG's 548MB)
    for din, dout in ((512 * 49, 4096), (4096, 4096), (4096, 1000)):
        fl.append(3.0 * 2 * batch * din * dout)
        pb.append(din * dout * 4.0)
        ab.append(batch * dout * 4.0)
    return np.array(fl), np.array(pb), np.array(ab)


def resnet152_layer_costs(batch: int = 32):
    """Bottleneck-block granularity (stem + 8/64/36/3 blocks... 3,8,36,3)."""
    stages = [(256, 64, 3, 56), (512, 128, 8, 28),
              (1024, 256, 36, 14), (2048, 512, 3, 7)]
    fl, pb, ab = [], [], []
    fl.append(3.0 * 2 * batch * 112 * 112 * 3 * 64 * 49)     # 7x7 stem
    pb.append(3 * 64 * 49 * 4.0)
    ab.append(batch * 112 * 112 * 64 * 4.0)
    for cout, mid, blocks, hw in stages:
        for b in range(blocks):
            cin = cout if b else (cout // 2 if cout > 256 else 64)
            f = 2 * batch * hw * hw * (cin * mid + mid * mid * 9 + mid * cout)
            p = (cin * mid + mid * mid * 9 + mid * cout) * 4.0
            fl.append(3.0 * f)
            pb.append(p)
            ab.append(batch * hw * hw * cout * 4.0)
    fl.append(3.0 * 2 * batch * 2048 * 1000)
    pb.append(2048 * 1000 * 4.0)
    ab.append(batch * 1000 * 4.0)
    return np.array(fl), np.array(pb), np.array(ab)


PAPER_MODELS = {"vgg19": vgg19_layer_costs, "resnet152": resnet152_layer_costs}


# ---- small runnable conv net (CPU smoke) -----------------------------------
def init_tiny_cnn(key, num_classes: int = 10, width: int = 8):
    ks = jax.random.split(key, 4)
    return {
        "c1": 0.1 * jax.random.normal(ks[0], (3, 3, 3, width)),
        "c2": 0.1 * jax.random.normal(ks[1], (3, 3, width, 2 * width)),
        "w": 0.1 * jax.random.normal(ks[2], (2 * width * 64, num_classes)),
        "b": jnp.zeros((num_classes,)),
    }


def tiny_cnn_apply(p, x):
    """x [B, 32, 32, 3] -> logits [B, classes]."""
    y = jax.lax.conv_general_dilated(
        x, p["c1"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y)
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    y = jax.lax.conv_general_dilated(
        y, p["c2"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y)
    y = jax.lax.reduce_window(y, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    return y.reshape(y.shape[0], -1) @ p["w"] + p["b"]
