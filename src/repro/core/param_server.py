"""Sharded parameter server for WSP data parallelism (paper Section 5).

Holds w_global as flat numpy shards (layer round-robin over PS shards — the
paper's 'default' placement; 'local' placement maps a shard to the node that
produces its partition, modeled by shard affinity metadata). Virtual workers
push *wave-aggregated deltas* ũ (one push per wave — the paper's communication
saving) and pull w_global under the WSP clock gate.

This is the host-level PS used by the threaded runtime (true asynchrony,
D >= 0). The SPMD dry-run path instead reduces wave deltas with collectives
(D = 0); both share the same WSP clock state machine.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import numpy as np

from repro.core.wsp import WSPClockServer
from repro.dist.compression import ErrorFeedbackCompressor, make_codec
from repro.dist.transport import NullTransport


def tree_flatten_np(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


class ParameterServer:
    def __init__(self, params_tree, *, D: int = 0, num_shards: int = 4,
                 placement: str = "default",
                 compression_ratio: Optional[float] = None,
                 codec=None, transport=None):
        leaves, self.treedef = tree_flatten_np(params_tree)
        self.shapes = [l.shape for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.flat = [l.astype(np.float32).ravel().copy() for l in leaves]
        self.num_shards = num_shards
        self.placement = placement
        # layer/leaf round-robin over shards (paper's default placement)
        self.shard_of_leaf = [i % num_shards for i in range(len(leaves))]
        self._locks = [threading.Lock() for _ in range(num_shards)]
        self.clock = WSPClockServer(D)
        self.push_count = 0
        self.bytes_pushed = 0
        self.bytes_wire = 0
        self.comm_seconds = 0.0
        self._stats_lock = threading.Lock()   # accounting fields above
        # a wave-completion signal for the trainer's supervision loop
        self.push_event = threading.Event()
        if codec is not None:
            self.compressor = make_codec(codec)
        else:
            self.compressor = (ErrorFeedbackCompressor(compression_ratio)
                               if compression_ratio else None)
        self.transport = transport if transport is not None \
            else NullTransport()

    # -- worker lifecycle -------------------------------------------------
    def register(self, wid: str):
        self.clock.register(wid)

    def deregister(self, wid: str):
        self.clock.deregister(wid)
        self.push_event.set()        # wake the supervision loop

    # -- WSP protocol -----------------------------------------------------
    def push_wave(self, wid: str, deltas_tree) -> int:
        """Apply a wave-aggregated delta; advances the worker's local clock.
        The wire bytes of the (possibly compressed) push transit the
        simulated transport before the update lands."""
        leaves, _ = tree_flatten_np(deltas_tree)
        updates, wire, dense = [], 0, 0
        for i, d in enumerate(leaves):
            flat = d.astype(np.float32).ravel()
            dense += flat.nbytes
            if self.compressor is not None:
                idx, vals = self.compressor.compress(f"{wid}/{i}", flat)
                wire += self.compressor.wire_bytes(idx, vals)
                updates.append((i, idx, vals))
            else:
                wire += flat.nbytes
                updates.append((i, None, flat))
        sec = self.transport.send(wid, "ps", wire)
        with self._stats_lock:
            self.bytes_pushed += dense
            self.bytes_wire += wire
            self.comm_seconds += sec
            self.push_count += 1
        for i, idx, vals in updates:
            with self._locks[self.shard_of_leaf[i]]:
                if idx is None:
                    self.flat[i] += vals
                else:
                    self.flat[i][idx] += vals
        clock = self.clock.complete_wave(wid)
        self.push_event.set()
        return clock

    def wait_pull_allowed(self, wid: str, timeout: float = 120.0) -> bool:
        return self.clock.wait_until_allowed(wid, timeout)

    def pull(self, wid: Optional[str] = None):
        """Snapshot of w_global (consistent per leaf). When the puller is
        identified, the full parameter payload transits the transport."""
        out = []
        nbytes = 0
        for i, f in enumerate(self.flat):
            with self._locks[self.shard_of_leaf[i]]:
                out.append(f.copy().reshape(self.shapes[i])
                           .astype(self.dtypes[i]))
            nbytes += f.nbytes
        if wid is not None:
            sec = self.transport.send("ps", wid, nbytes)
            with self._stats_lock:
                self.comm_seconds += sec
        return jax.tree.unflatten(self.treedef, out)

    # -- checkpointing ----------------------------------------------------
    def state_dict(self):
        return {
            "flat": [f.copy() for f in self.flat],
            "clocks": dict(self.clock.state.clocks),
            "push_count": self.push_count,
        }

    def load_state_dict(self, sd):
        for i, f in enumerate(sd["flat"]):
            self.flat[i][:] = f
        self.clock.state.clocks = dict(sd["clocks"])
        self.push_count = sd["push_count"]
