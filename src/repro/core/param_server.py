"""Sharded parameter server for WSP data parallelism (paper Section 5).

Holds w_global as flat numpy shards (layer round-robin over PS shards — the
paper's 'default' placement; 'local' placement maps a shard to the node that
produces its partition, modeled by shard affinity metadata). Virtual workers
push *wave-aggregated deltas* ũ (one push per wave — the paper's communication
saving) and pull w_global under the WSP clock gate.

A push is split into begin_push (compress + start the transport transfer,
without blocking) and finish_push (wait for the wire, apply shard-grouped
updates, advance the WSP clock); push_wave() chains the two. The async
runtime hands the raw delta to a per-worker outbox thread which runs the
whole push_wave off the worker's critical path — compression, wire
accounting, and the transport delay all land on the outbox thread while the
worker computes its next wave.

This is the host-level PS used by the threaded runtime (true asynchrony,
D >= 0). The SPMD dry-run path instead reduces wave deltas with collectives
(D = 0); both share the same WSP clock state machine.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np

from repro.core.wsp import WSPClockServer
from repro.dist.compression import ErrorFeedbackCompressor, make_codec
from repro.dist.transport import AsyncSend, NullTransport


def tree_flatten_np(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


@dataclass
class PendingPush:
    """A push whose wire transfer has been issued but not yet applied."""
    wid: str
    updates: list                      # [(leaf_idx, topk_idx | None, vals)]
    send: AsyncSend
    applied: bool = field(default=False)


class ParameterServer:
    def __init__(self, params_tree, *, D: int = 0, num_shards: int = 4,
                 placement: str = "default",
                 compression_ratio: Optional[float] = None,
                 codec=None, transport=None, tracer=None, injector=None):
        if tracer is None:
            from repro.obs import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        leaves, self.treedef = tree_flatten_np(params_tree)
        self.shapes = [l.shape for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.flat = [l.astype(np.float32).ravel().copy() for l in leaves]
        self.num_shards = num_shards
        self.placement = placement
        # layer/leaf round-robin over shards (paper's default placement)
        self.shard_of_leaf = [i % num_shards for i in range(len(leaves))]
        self._locks = [threading.Lock() for _ in range(num_shards)]
        # serializes the apply+clock-advance of a push against snapshots:
        # a checkpoint must never capture a clock that counts a push whose
        # weights it missed (push lost on resume) or the reverse (push
        # double-applied when the worker redoes the wave)
        self._snapshot_lock = threading.RLock()
        # per-shard monotone version, bumped on every push that touches the
        # shard; pull() reuses a cached leaf snapshot while versions match
        self._shard_version = [0] * num_shards
        self._leaf_cache: list = [None] * len(leaves)
        self.clock = WSPClockServer(D)
        self.injector = injector          # repro.faults.FaultInjector | None
        self.push_count = 0
        self.bytes_pushed = 0
        self.bytes_wire = 0
        self.comm_seconds = 0.0
        self.pull_count = 0
        self.pull_cache_hits = 0          # leaf snapshots served from cache
        self.late_pushes = 0              # applied after the pusher left
        self.ps_stalls = 0                # injected apply stalls taken
        self._stats_lock = threading.Lock()   # accounting fields above
        # a wave-completion signal for the trainer's supervision loop
        self.push_event = threading.Event()
        if codec is not None:
            self.compressor = make_codec(codec)
        else:
            self.compressor = (ErrorFeedbackCompressor(compression_ratio)
                               if compression_ratio else None)
        self.transport = transport if transport is not None \
            else NullTransport()

    # -- worker lifecycle -------------------------------------------------
    def register(self, wid: str):
        self.clock.register(wid)

    def deregister(self, wid: str):
        self.clock.deregister(wid)
        self.push_event.set()        # wake the supervision loop

    # -- WSP protocol -----------------------------------------------------
    def begin_push(self, wid: str, deltas_tree) -> PendingPush:
        """Compress a wave-aggregated delta and start its wire transfer.
        Does not block on the (simulated) network and does not touch
        w_global; the caller finishes with finish_push."""
        leaves, _ = tree_flatten_np(deltas_tree)
        updates, wire, dense = [], 0, 0
        for i, d in enumerate(leaves):
            flat = d.astype(np.float32).ravel()
            dense += flat.nbytes
            if self.compressor is not None:
                idx, vals = self.compressor.compress(f"{wid}/{i}", flat)
                wire += self.compressor.wire_bytes(idx, vals)
                updates.append((i, idx, vals))
            else:
                wire += flat.nbytes
                updates.append((i, None, flat))
        send = self.transport.send_async(wid, "ps", wire)
        with self._stats_lock:
            self.bytes_pushed += dense
            self.bytes_wire += wire
            self.comm_seconds += send.seconds
        return PendingPush(wid, updates, send)

    def finish_push(self, pending: PendingPush) -> int:
        """Wait for the wire, apply the update (one lock acquisition per
        touched shard), advance the worker's WSP clock.

        Fault semantics: a transport whose retry budget is exhausted
        surfaces here as the typed PushTimeout (the wave's delta never
        reached the PS — nothing is applied, the clock does not move). A
        push from a worker that was evicted while its transfer was in
        flight still applies — the delta is a stale-but-sound gradient —
        but never advances the departed worker's clock (`late_pushes`),
        so eviction cannot move the global minimum past what survivors
        gated against."""
        assert not pending.applied, "finish_push called twice"
        try:
            pending.send.wait()
        except Exception as e:
            from repro.faults.errors import FaultError, PushTimeout
            if isinstance(e, FaultError):
                raise PushTimeout(pending.wid, e) from e
            raise
        by_shard: dict[int, list] = {}
        for upd in pending.updates:
            by_shard.setdefault(self.shard_of_leaf[upd[0]], []).append(upd)
        with self.tracer.span("ps", "push_apply", wid=pending.wid,
                              shards=len(by_shard)), self._snapshot_lock:
            if self.injector is not None:
                # push_count is stable under the snapshot lock, so which
                # push a PSStall lands on is deterministic
                stall = self.injector.ps_stall_sleep(self.push_count)
                if stall > 0:
                    self.ps_stalls += 1
                    self.tracer.instant("ps", "stall", wid=pending.wid,
                                        push=self.push_count, seconds=stall)
                    self.tracer.metrics.counter_inc("fault/ps_stalls")
                    import time
                    time.sleep(stall)
            for sid, ups in by_shard.items():
                with self._locks[sid]:
                    for i, idx, vals in ups:
                        if idx is None:
                            self.flat[i] += vals
                        else:
                            self.flat[i][idx] += vals
                    self._shard_version[sid] += 1
            pending.applied = True
            # counted at apply time (not issue time) so a snapshot's
            # push_count is exactly the number of pushes its weights contain
            self.push_count += 1
            clock = self.clock.complete_wave_if_registered(pending.wid)
            if clock is None:
                self.late_pushes += 1
                self.tracer.instant("ps", "late_push", wid=pending.wid)
                self.tracer.metrics.counter_inc("fault/late_pushes")
                clock = -1
        self.push_event.set()
        return clock

    def push_wave(self, wid: str, deltas_tree) -> int:
        """Blocking push: the wire bytes of the (possibly compressed) push
        transit the simulated transport before the update lands."""
        return self.finish_push(self.begin_push(wid, deltas_tree))

    def wait_pull_allowed(self, wid: str, timeout: float = 120.0,
                          at_clock: Optional[int] = None) -> bool:
        return self.clock.wait_until_allowed(wid, timeout, at_clock)

    def gate(self, wid: str, timeout: float = 120.0,
             at_clock: Optional[int] = None) -> bool:
        """Typed staleness gate: True when `wid` may start its next wave,
        False when it was deregistered (evicted) while waiting, and the
        typed GateTimeout when the global clock failed to catch up within
        `timeout` — a stuck fleet must fail loudly, never truncate
        silently (wait_pull_allowed's boolean conflates the two)."""
        import time as _time
        t0 = _time.monotonic()
        reason = self.clock.wait_reason(wid, timeout, at_clock)
        if reason == "timeout":
            from repro.faults.errors import GateTimeout
            wave = at_clock if at_clock is not None else \
                self.clock.state.clocks.get(wid, -1)
            raise GateTimeout(wid, wave, _time.monotonic() - t0)
        return reason == "ok"

    def pull(self, wid: Optional[str] = None):
        """Snapshot of w_global (consistent per leaf). Leaves whose shard
        version is unchanged since the last pull are served from a cached
        snapshot instead of re-copied — the returned arrays are shared
        between pullers and must be treated as read-only. When the puller is
        identified, the full parameter payload transits the transport."""
        with self.tracer.span("ps", "pull_serve",
                              wid=wid if wid is not None else "snapshot"):
            out = []
            nbytes = 0
            hits = 0
            for i, f in enumerate(self.flat):
                sid = self.shard_of_leaf[i]
                with self._locks[sid]:
                    ver = self._shard_version[sid]
                    cached = self._leaf_cache[i]
                    if cached is not None and cached[0] == ver:
                        arr = cached[1]
                        hits += 1
                    else:
                        # astype always copies, detaching the snapshot from
                        # flat
                        arr = (f.reshape(self.shapes[i])
                               .astype(self.dtypes[i]))
                        # the snapshot is shared between pullers and with the
                        # cache: an in-place mutation must fail loudly, not
                        # corrupt every other worker's view
                        arr.flags.writeable = False
                        self._leaf_cache[i] = (ver, arr)
                out.append(arr)
                nbytes += f.nbytes
            with self._stats_lock:
                self.pull_count += 1
                self.pull_cache_hits += hits
        if wid is not None:
            sec = self.transport.send("ps", wid, nbytes)
            with self._stats_lock:
                self.comm_seconds += sec
        return jax.tree.unflatten(self.treedef, out)

    # -- checkpointing ----------------------------------------------------
    def state_dict(self):
        with self._snapshot_lock:
            return {
                "flat": [f.copy() for f in self.flat],
                "clocks": dict(self.clock.state.clocks),
                "push_count": self.push_count,
            }

    def checkpoint_state(self):
        """(params_tree, meta) snapshotted atomically with respect to pushes:
        the weights include exactly the waves the clocks count, so a resume
        neither loses nor double-applies an in-flight async push."""
        with self.tracer.span("ps", "snapshot"), self._snapshot_lock:
            params = self.pull()
            meta = {"clocks": dict(self.clock.state.clocks),
                    "push_count": self.push_count}
        return params, meta

    def load_state_dict(self, sd):
        for i, f in enumerate(sd["flat"]):
            self.flat[i][:] = f
        self._shard_version = [v + 1 for v in self._shard_version]
        self.clock.state.clocks = dict(sd["clocks"])
        self.push_count = sd["push_count"]
