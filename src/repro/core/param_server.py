"""Sharded parameter server for WSP data parallelism (paper Section 5).

Holds w_global as flat numpy shards (layer round-robin over PS shards — the
paper's 'default' placement; 'local' placement maps a shard to the node that
produces its partition, modeled by shard affinity metadata). Virtual workers
push *wave-aggregated deltas* ũ (one push per wave — the paper's communication
saving) and pull w_global under the WSP clock gate.

This is the host-level PS used by the threaded runtime (true asynchrony,
D >= 0). The SPMD dry-run path instead reduces wave deltas with collectives
(D = 0); both share the same WSP clock state machine.
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import numpy as np

from repro.core.wsp import WSPClockServer
from repro.dist.compression import ErrorFeedbackCompressor


def tree_flatten_np(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(l) for l in leaves], treedef


class ParameterServer:
    def __init__(self, params_tree, *, D: int = 0, num_shards: int = 4,
                 placement: str = "default",
                 compression_ratio: Optional[float] = None):
        leaves, self.treedef = tree_flatten_np(params_tree)
        self.shapes = [l.shape for l in leaves]
        self.dtypes = [l.dtype for l in leaves]
        self.flat = [l.astype(np.float32).ravel().copy() for l in leaves]
        self.num_shards = num_shards
        self.placement = placement
        # layer/leaf round-robin over shards (paper's default placement)
        self.shard_of_leaf = [i % num_shards for i in range(len(leaves))]
        self._locks = [threading.Lock() for _ in range(num_shards)]
        self.clock = WSPClockServer(D)
        self.push_count = 0
        self.bytes_pushed = 0
        self.bytes_wire = 0
        self.compressor = (ErrorFeedbackCompressor(compression_ratio)
                           if compression_ratio else None)

    # -- worker lifecycle -------------------------------------------------
    def register(self, wid: str):
        self.clock.register(wid)

    def deregister(self, wid: str):
        self.clock.deregister(wid)

    # -- WSP protocol -----------------------------------------------------
    def push_wave(self, wid: str, deltas_tree) -> int:
        """Apply a wave-aggregated delta; advances the worker's local clock."""
        leaves, _ = tree_flatten_np(deltas_tree)
        for i, d in enumerate(leaves):
            flat = d.astype(np.float32).ravel()
            self.bytes_pushed += flat.nbytes
            if self.compressor is not None:
                idx, vals = self.compressor.compress(f"{wid}/{i}", flat)
                self.bytes_wire += self.compressor.wire_bytes(idx, vals)
                with self._locks[self.shard_of_leaf[i]]:
                    self.flat[i][idx] += vals
            else:
                self.bytes_wire += flat.nbytes
                with self._locks[self.shard_of_leaf[i]]:
                    self.flat[i] += flat
        self.push_count += 1
        return self.clock.complete_wave(wid)

    def wait_pull_allowed(self, wid: str, timeout: float = 120.0) -> bool:
        return self.clock.wait_until_allowed(wid, timeout)

    def pull(self):
        """Snapshot of w_global (consistent per leaf)."""
        out = []
        for i, f in enumerate(self.flat):
            with self._locks[self.shard_of_leaf[i]]:
                out.append(f.copy().reshape(self.shapes[i])
                           .astype(self.dtypes[i]))
        return jax.tree.unflatten(self.treedef, out)

    # -- checkpointing ----------------------------------------------------
    def state_dict(self):
        return {
            "flat": [f.copy() for f in self.flat],
            "clocks": dict(self.clock.state.clocks),
            "push_count": self.push_count,
        }

    def load_state_dict(self, sd):
        for i, f in enumerate(sd["flat"]):
            self.flat[i][:] = f
        self.clock.state.clocks = dict(sd["clocks"])
        self.push_count = sd["push_count"]
