"""Heterogeneity-aware model partitioner (paper Section 7).

The paper solves min-max stage time with CPLEX; because HetPipe partitions are
*contiguous layer ranges* assigned to a fixed device order, exact dynamic
programming is sufficient: O(L^2 k) over (first l layers, s stages), taking the
paper's position-dependent memory model as a feasibility constraint.

Memory model (paper Section 4): the number of in-flight activation sets at
stage s (1-indexed, k stages) under 1F1B continuous injection is
min(Nm, 2*(k - s) + 1) — stage 1 retains activations across the whole pipeline
round trip, the last stage retires each minibatch immediately.

Costs come from an analytic per-layer performance model (flops / device flops
+ activation bytes / link bandwidth), the TPU analogue of the paper's profiling
+ linear-regression communication model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    tflops: float            # peak bf16 TFLOP/s
    mem_gb: float            # HBM per device
    link_gbps: float = 50.0  # inter-stage link bandwidth (GB/s)
    mfu: float = 0.45        # achievable fraction of peak in steady state

    @property
    def eff_flops(self) -> float:
        return self.tflops * 1e12 * self.mfu


# TPU production profile + the paper's heterogeneous GPU fleet (Table 1),
# expressed in the same units so the allocation benchmarks can reproduce the
# paper's setting analytically.
TPU_V5E = DeviceProfile("tpu_v5e", 197.0, 16.0, 50.0)
PAPER_GPUS = {
    "V": DeviceProfile("TITAN V", 29.8, 12.0, 15.75),       # fp16 TFLOPs
    "R": DeviceProfile("TITAN RTX", 32.6, 24.0, 15.75),
    "G": DeviceProfile("RTX 2060", 12.9, 6.0, 15.75),
    "Q": DeviceProfile("Quadro P4000", 5.3, 8.0, 15.75),
}


def layer_costs(cfg: ArchConfig, seq_len: int, mb_tokens: int):
    """Per-layer (flops, param_bytes, act_bytes) for one microbatch.

    flops: forward+backward (3x fwd matmul flops, the standard estimate).
    act_bytes: the inter-layer activation (what crosses a stage boundary and
    what 1F1B keeps resident), bf16.
    """
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    G = 2 if cfg.mlp_type in ("swiglu", "geglu") else 1
    T = mb_tokens
    fl = []
    kinds = cfg.layer_kinds()[: cfg.num_layers]
    for kind in kinds:
        f = 0.0
        if cfg.attn_type != "none" and kind != 2:
            f += 2 * T * d * (H + 2 * KV) * hd          # qkv proj
            f += 2 * T * H * hd * d                     # out proj
            ctx = seq_len if kind == 0 else min(cfg.window_size, seq_len)
            f += 2 * 2 * T * ctx * H * hd               # qk + pv
        if cfg.ssm_type == "rwkv6":
            f += 2 * T * d * d * 5                      # r,k,v,g,o projections
            f += 2 * T * cfg.n_ssm_heads * (d // cfg.n_ssm_heads) ** 2 * 2
        if cfg.ssm_type == "ssd":
            di, N = cfg.d_inner, cfg.ssm_state
            f += 2 * T * d * (2 * di + 2 * N + cfg.n_ssm_heads)
            f += 2 * T * di * d
            f += 2 * T * di * N * 2                     # state in/out
        if cfg.num_experts:
            f += 2 * T * d * cfg.num_experts            # router
            f += 2 * T * cfg.top_k * (d * ff * G + ff * d)
        elif cfg.attn_type != "none":
            f += 2 * T * (d * ff * G + ff * d)
        else:                                           # rwkv channel mix
            f += 2 * T * (d * ff + ff * d + d * d)
        fl.append(3.0 * f)                              # fwd + bwd
    param_b = np.full(cfg.num_layers,
                      (cfg.param_count() - cfg.vocab_size * cfg.d_model *
                       (1 if cfg.tie_embeddings or cfg.frontend != "none"
                        else 2)) / max(cfg.num_layers, 1) * 4.0)
    act_b = np.full(cfg.num_layers, T * d * 2.0)
    return np.array(fl), param_b, act_b


def inflight(stage: int, k: int, nm: int) -> int:
    """In-flight activation sets at `stage` (0-indexed) under 1F1B."""
    return min(nm, 2 * (k - 1 - stage) + 1)


def partition_minmax(flops: np.ndarray, act_bytes: np.ndarray,
                     param_bytes: np.ndarray,
                     devices: list[DeviceProfile], nm: int,
                     *, opt_bytes_per_param: float = 3.0,
                     links: list | None = None, overlap: bool = False):
    """Exact DP min-max contiguous partition of L layers over k ordered devices.

    Returns (boundaries, stage_times, feasible). boundaries[i] = first layer of
    stage i+1; stage i covers layers [boundaries[i-1], boundaries[i]).

    `links` prices each stage boundary with a real link (any object with a
    LinkSpec-style transfer_time(nbytes), e.g. from repro.dist.topology's
    stage_links / ClusterTopology.path_links): links[s] joins stage s to
    s+1, so alpha (per-message latency) and heterogeneous inter-stage
    bandwidth both enter the cut. Without it, the legacy per-device
    link_gbps (pure bandwidth) is used.

    `overlap` makes the stage cost comm/compute-overlap-aware: a stage that
    sends its boundary activation while computing the next microbatch (the
    skewed pipeline schedule) is gated by max(compute, comm) instead of
    their sum — the DP then picks different cuts on overlap-capable
    clusters (it can afford comm-heavy boundaries next to compute-heavy
    stages).
    """
    L, k = len(flops), len(devices)
    if links is not None and len(links) != k - 1:
        raise ValueError(f"links has {len(links)} entries for {k} stages "
                         f"(expected k-1 boundary links)")
    pre_f = np.concatenate([[0.0], np.cumsum(flops)])
    pre_p = np.concatenate([[0.0], np.cumsum(param_bytes)])

    def boundary_comm(b: int, s: int) -> float:
        if b >= L:                                   # last stage sends nothing
            return 0.0
        if links:
            # clamp only for the DP's dead intermediate states (last stage
            # with b < L, never part of the final traceback)
            return links[min(s, len(links) - 1)].transfer_time(
                float(act_bytes[b - 1]))
        return act_bytes[b - 1] / (devices[s].link_gbps * 1e9)

    def stage_time(a: int, b: int, s: int) -> float:
        comp = (pre_f[b] - pre_f[a]) / devices[s].eff_flops
        comm = boundary_comm(b, s)                   # send boundary activation
        return max(comp, comm) if overlap else comp + comm

    def stage_mem(a: int, b: int, s: int) -> float:
        m = (pre_p[b] - pre_p[a]) * (1.0 + opt_bytes_per_param)
        m += float(np.sum(act_bytes[a:b])) * inflight(s, k, nm)
        return m

    INF = float("inf")
    f = np.full((L + 1, k + 1), INF)
    arg = np.full((L + 1, k + 1), -1, np.int64)
    f[0, 0] = 0.0
    for s in range(1, k + 1):
        budget = devices[s - 1].mem_gb * 1e9
        for b in range(s, L - (k - s) + 1):
            best, bj = INF, -1
            for a in range(s - 1, b):
                if f[a, s - 1] == INF:
                    continue
                if stage_mem(a, b, s - 1) > budget:
                    continue
                c = max(f[a, s - 1], stage_time(a, b, s - 1))
                if c < best:
                    best, bj = c, a
            f[b, s], arg[b, s] = best, bj
    feasible = f[L, k] < INF
    if not feasible:
        return None, None, False
    bounds = [L]
    b = L
    for s in range(k, 0, -1):
        b = int(arg[b, s])
        bounds.append(b)
    bounds = bounds[::-1]                            # [0, ..., L]
    times = [stage_time(bounds[i], bounds[i + 1], i) for i in range(k)]
    return bounds, times, True


def max_concurrent_minibatches(cfg: ArchConfig, devices: list[DeviceProfile],
                               seq_len: int, mb_tokens: int,
                               nm_cap: int = 32, **part_kw) -> int:
    """Paper's Max_m: the largest Nm for which a feasible partition exists."""
    fl, pb, ab = layer_costs(cfg, seq_len, mb_tokens)
    best = 0
    for nm in range(1, nm_cap + 1):
        _, _, ok = partition_minmax(fl, ab, pb, devices, nm, **part_kw)
        if ok:
            best = nm
        else:
            break
    return best


def pipeline_throughput(times: list[float], nm: int, schedule: str = "1f1b",
                        *, comm_times: list[float] | None = None,
                        overlap: bool = False):
    """Minibatches/sec of the steady-state pipeline given stage times.

    gpipe: wave of Nm drains per wave -> wave time = (Nm-1)*t_max + sum(t).
    1f1b : continuous injection with Nm in-flight slots -> the pipe saturates
           at 1/t_max once Nm covers the round trip (Nm jobs circulating a
           ring of latency ~sum(t) fwd + bwd).

    When `comm_times` (per-stage boundary-send seconds) is given, the
    effective per-stage time is compute+comm, or max(compute, comm) under the
    overlapped schedule — partition_minmax(..., overlap=...) already folds
    this in, so pass comm_times only for times that are compute-only. A
    k-stage pipeline has k-1 boundaries, so a length-(k-1) vector (e.g. from
    stage_links / path_links) is padded with a free last boundary.
    """
    if comm_times is not None:
        if len(comm_times) == len(times) - 1:
            comm_times = list(comm_times) + [0.0]
        if len(comm_times) != len(times):
            raise ValueError(f"comm_times has {len(comm_times)} entries for "
                             f"{len(times)} stages (expected k or k-1)")
        times = [max(t, c) if overlap else t + c
                 for t, c in zip(times, comm_times)]
    t_max, t_sum = max(times), sum(times)
    if schedule == "gpipe":
        return nm / ((nm - 1) * t_max + t_sum)
    return min(1.0 / t_max, nm / (2.0 * t_sum))
