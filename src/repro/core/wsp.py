"""Wave Synchronous Parallel (WSP) clock machinery — paper Sections 4-5.

Definitions (paper):
  wave           = s_local + 1 = Nm minibatches processed concurrently by a VW
  local clock c  = number of waves a virtual worker has completed
  global clock   = min over VW local clocks
  staleness D    = max allowed clock distance between fastest and slowest VW

Gating rule: a VW about to *start* wave c must use weights that include every
wave aggregate through wave c - D - 1 from ALL virtual workers; equivalently it
blocks while c_global < c - D.

Thread-safe; supports elastic add/remove of virtual workers (a removed VW's
clock simply leaves the min — WSP's proof is parameterized by the live count N).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field


class StalenessViolation(AssertionError):
    pass


@dataclass
class WSPClockState:
    """Pure (lock-free) clock logic, separated for property testing."""
    D: int
    clocks: dict[str, int] = field(default_factory=dict)

    def add_worker(self, wid: str, clock: int | None = None):
        # an elastically (re-)joining worker starts at the global clock: it
        # pulls w_global which contains every wave through c_global - 1.
        self.clocks[wid] = self.global_clock() if clock is None else clock

    def remove_worker(self, wid: str):
        # idempotent: eviction (supervisor) and self-deregistration (the
        # worker's own exit path) may race on the same wid
        self.clocks.pop(wid, None)

    def global_clock(self) -> int:
        return min(self.clocks.values()) if self.clocks else 0

    def can_proceed(self, wid: str, at_clock: int | None = None) -> bool:
        """May `wid` start its next wave (local clock c = clocks[wid])?

        `at_clock` evaluates the same gate at a *logical* clock value: an
        async-pushing worker whose wave-c push is still in flight has
        clocks[wid] < c, but must gate wave c+1 as if the push had landed —
        otherwise overlap would silently buy an extra unit of staleness."""
        c = self.clocks[wid] if at_clock is None else at_clock
        return c - self.D <= self.global_clock()

    def complete_wave(self, wid: str) -> int:
        if not self.can_proceed(wid):
            raise StalenessViolation(
                f"{wid} completed a wave it was not allowed to start: "
                f"local={self.clocks[wid]} global={self.global_clock()} "
                f"D={self.D}")
        self.clocks[wid] += 1
        return self.clocks[wid]

    def max_distance(self) -> int:
        if not self.clocks:
            return 0
        return max(self.clocks.values()) - min(self.clocks.values())


class WSPClockServer:
    """Blocking facade used by the threaded runtime."""

    def __init__(self, D: int):
        self.state = WSPClockState(D)
        self._cv = threading.Condition()
        self.wait_seconds: dict[str, float] = {}

    def register(self, wid: str):
        with self._cv:
            self.state.add_worker(wid)
            self.wait_seconds.setdefault(wid, 0.0)
            self._cv.notify_all()

    def deregister(self, wid: str):
        with self._cv:
            self.state.remove_worker(wid)
            self._cv.notify_all()

    def local_clock(self, wid: str) -> int:
        with self._cv:
            return self.state.clocks[wid]

    def global_clock(self) -> int:
        with self._cv:
            return self.state.global_clock()

    def wait_until_allowed(self, wid: str, timeout: float = 120.0,
                           at_clock: int | None = None) -> bool:
        """Block until `wid` may start its next wave. Returns False on timeout
        or if the worker was deregistered while waiting."""
        return self.wait_reason(wid, timeout, at_clock) == "ok"

    def wait_reason(self, wid: str, timeout: float = 120.0,
                    at_clock: int | None = None) -> str:
        """Like wait_until_allowed but disambiguates the failure:
        'ok' | 'timeout' | 'evicted' (deregistered while waiting — the
        supervisor pulled this worker out of the clock). The fault layer
        needs the distinction: a timeout is a GateTimeout error, an
        eviction is an orderly exit."""
        import time
        t0 = time.monotonic()
        reason = "ok"
        with self._cv:
            while wid in self.state.clocks and \
                    not self.state.can_proceed(wid, at_clock):
                remaining = timeout - (time.monotonic() - t0)
                if remaining <= 0:
                    reason = "timeout"
                    break
                self._cv.wait(remaining)
            if reason == "ok" and wid not in self.state.clocks:
                reason = "evicted"
        self.wait_seconds[wid] = self.wait_seconds.get(wid, 0.0) + (
            time.monotonic() - t0)
        return reason

    def complete_wave(self, wid: str) -> int:
        with self._cv:
            c = self.state.complete_wave(wid)
            self._cv.notify_all()
            return c

    def complete_wave_if_registered(self, wid: str) -> int | None:
        """Advance `wid`'s clock iff it is still registered; None if it was
        deregistered (evicted) meanwhile. The async-push landing path uses
        this so a crashed worker's in-flight push can never advance the
        clock of a worker that has already left the fleet — which would
        move the global minimum past what survivors gated against."""
        with self._cv:
            if wid not in self.state.clocks:
                return None
            c = self.state.complete_wave(wid)
            self._cv.notify_all()
            return c
