"""Resource allocation policies (paper Section 8.1): map a heterogeneous
device fleet onto virtual workers.

  NP (Node Partition)     — one node per VW (homogeneous VW, straggler-prone)
  ED (Equal Distribution) — every VW gets one device of each type
  HD (Hybrid Distribution)— pair strong+weak types so VW aggregate
                            compute/memory is balanced

The allocator returns per-VW ordered device lists (pipeline stage order) plus
an analytic straggler report; the partitioner (core.partition) then cuts the
model per VW. On a homogeneous TPU pod every policy degenerates to equal
slices — heterogeneity enters via device profiles (mixed fleets, degraded
nodes), which the threaded runtime can also simulate.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.partition import (DeviceProfile, layer_costs,
                                  partition_minmax, pipeline_throughput)


@dataclass(frozen=True)
class Node:
    gpu: DeviceProfile
    count: int


def allocate(nodes: list[Node], policy: str, num_vw: int | None = None):
    """Returns list of VWs, each an ordered list of DeviceProfile."""
    if num_vw is None:
        num_vw = len(nodes)
    if policy == "NP":
        assert num_vw == len(nodes)
        return [[n.gpu] * n.count for n in nodes]
    per_vw = sum(n.count for n in nodes) // num_vw
    if policy == "ED":
        pool = [n.gpu for n in nodes for _ in range(n.count)]
        vws = [[] for _ in range(num_vw)]
        for i, g in enumerate(pool):
            vws[i % num_vw].append(g)
        return [sorted(vw, key=lambda g: -g.tflops) for vw in vws]
    if policy == "HD":
        # paper Table 3: pair the i-th strongest type with the i-th weakest
        # (VVQQ / RRGG) so per-VW aggregate compute+memory is balanced
        order = sorted(nodes, key=lambda n: -n.gpu.tflops)
        vws = []
        half = per_vw // 2
        for j in range(len(order) // 2):
            a, b = order[j], order[len(order) - 1 - j]
            pool_a = [a.gpu] * a.count
            pool_b = [b.gpu] * b.count
            while pool_a or pool_b:
                vw = [pool_a.pop() for _ in range(min(half, len(pool_a)))]
                vw += [pool_b.pop() for _ in
                       range(min(per_vw - len(vw), len(pool_b)))]
                while len(vw) < per_vw and pool_a:
                    vw.append(pool_a.pop())
                vws.append(vw)
        assert len(vws) == num_vw, (len(vws), num_vw)
        return vws
    raise ValueError(policy)


def vw_throughputs(cfg, vws, seq_len: int, mb_tokens: int, nm: int,
                   schedule: str = "1f1b", *, inter=None,
                   overlap: bool = False):
    """Analytic per-VW minibatch throughput under the min-max partition.

    `inter` (a repro.dist.topology.LinkSpec) prices each stage boundary with
    real links via stage_links — consecutive same-profile devices share a
    node, a profile change crosses `inter`. `overlap` gates each stage at
    max(compute, comm) instead of the sum (the skewed pipeline schedule)."""
    if inter is not None:
        from repro.dist.topology import stage_links
    out = []
    fl, pb, ab = layer_costs(cfg, seq_len, mb_tokens)
    for vw in vws:
        links = stage_links(vw, inter) if inter is not None else None
        res = partition_minmax(fl, ab, pb, vw, nm, links=links,
                               overlap=overlap)
        if not res[2]:
            out.append(0.0)
            continue
        _, times, _ = res
        out.append(pipeline_throughput(times, nm, schedule))
    return np.array(out)


def straggler_report(throughputs: np.ndarray) -> dict:
    t = throughputs[throughputs > 0]
    if len(t) == 0:
        return {"feasible": False}
    return {
        "feasible": True,
        "min": float(t.min()), "max": float(t.max()),
        "imbalance": float(t.max() / t.min()),
        # BSP DP rate is gated by the slowest VW; WSP(D>0) approaches the mean
        "bsp_rate": float(len(t) * t.min()),
        "wsp_rate": float(t.sum()),
    }


def straggler_report_comm(throughputs: np.ndarray, topology,
                          bytes_per_wave: float) -> dict:
    """Comm-aware straggler report: each VW's wave time gains the modeled
    cost of pushing its wave delta to the parameter server over its link
    (repro.dist.topology). A VW on a slow inter-node link can become the
    straggler even when compute is balanced — the paper's motivation for
    folding the profiled network into placement (Section 7)."""
    th = np.asarray(throughputs, np.float64)
    comm = np.array([topology.p2p_cost(f"vw{i}", "ps", bytes_per_wave)
                     for i in range(len(th))])
    eff = np.where(th > 0, 1.0 / (1.0 / np.where(th > 0, th, 1.0) + comm),
                   0.0)
    rep = straggler_report(eff)
    rep["comm_seconds"] = [float(c) for c in comm]
    rep["compute_only"] = straggler_report(th)
    return rep
