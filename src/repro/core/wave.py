"""The pipelined wave step — HetPipe's virtual-worker PMP in SPMD JAX.

One jitted call processes one *wave* (Nm minibatches) through the pipeline:
  - the `model` mesh axis hosts stage x tp (paper: the k GPUs of a virtual
    worker); stages exchange boundary activations with lax.ppermute inside a
    scan over pipeline ticks (Nm + stages - 1 ticks; bubble ticks execute
    masked garbage, so compiled HLO FLOPs honestly include the pipeline bubble)
  - `data` (x `pod`) axes index virtual workers; the wave-aggregated update is
    reduced across them once per wave (WSP's per-wave sync; D=0 in SPMD — the
    threaded runtime provides true-async D>0 via the parameter server)

All microbatch packing/unpacking happens VW-locally inside the shard_map body,
so no global resharding is introduced around the pipeline. The same machinery
drives train (AD through the pipeline scan), prefill and decode (fwd-only,
KV/SSM caches updated in the scan carry).
"""
from __future__ import annotations

import functools
from dataclasses import replace as dc_replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig, RunConfig
from repro.models import lm
from repro.models.blocks import LayerCtx, apply_layer
from repro.models.layers import chunked_cross_entropy
from repro.optim import make_optimizer
from repro.serve import cache as cache_lib

S_AX, T_AX, D_AX = "stage", "tp", "data"


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", D_AX) if a in mesh.axis_names)


def tick_schedule(stages: int, nm: int, *, overlap: bool = False
                  ) -> tuple[list, int]:
    """The pipeline schedule pipeline_wave executes, as data: a list of
    (stage, tick, mb) entries — mb = -1 for bubble ticks — plus the tick
    count. Microbatch j reaches stage s at tick j + skew*s (skew 2 under
    the software-pipelined overlap schedule, else 1), exactly the mb_idx
    arithmetic in pipeline_wave.tick. Observability renders this as
    per-stage trace tracks; bubble fraction = 1 - nm*stages/len(entries)."""
    skew = 2 if overlap else 1
    ticks = nm + skew * (stages - 1)
    sched = []
    for s in range(stages):
        for t in range(ticks):
            mb = t - skew * s
            sched.append((s, t, mb if 0 <= mb < nm else -1))
    return sched, ticks


def n_dp(mesh: Mesh) -> int:
    axes = dp_axes(mesh)
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


# ----------------------------------------------------------------------------
# per-device pipeline (called inside shard_map)
# ----------------------------------------------------------------------------
def _stage_apply(cfg, blocks_local, x, meta_arrs, ctx: LayerCtx, cache_local):
    """Unrolled layer slots with tick-validity threaded into each layer."""
    aux = jnp.zeros((), jnp.float32)
    uk = lm.uniform_kind(cfg)
    base_valid = ctx.valid
    for s in range(cfg.layer_slots):
        p_l = jax.tree.map(lambda a: a[s], blocks_local)
        ctx_s = dc_replace(
            ctx,
            kind=uk if uk is not None else meta_arrs["kind"][s],
            valid=base_valid if uk is not None
            else jnp.logical_and(base_valid, meta_arrs["valid"][s]),
            full_i=meta_arrs["full_i"][s],
            win_i=meta_arrs["win_i"][s],
            ssm_i=s,
        )
        x, cache_local, a = apply_layer(cfg, p_l, x, ctx_s, cache_local)
        aux = aux + a
    return x, cache_local, aux


def pipeline_wave(cfg: ArchConfig, blocks_local, x_local, meta_local, *,
                  mode: str, nm: int, cache_local=None, pos=None, lens=None,
                  tp_axis: Optional[str], merge_axis: Optional[str],
                  seq_offset=0, remat: bool = False, overlap: bool = False,
                  kernel_backend: str = "ref"):
    """x_local [Bl, S, d] (this VW's wave batch). Returns (y [Bl,S,d] — valid
    on the last stage — cache_local, aux).

    overlap=False is the baseline (oracle) schedule: each tick computes and
    then ppermutes its output, so the boundary transfer sits on the critical
    path between consecutive stages.

    overlap=True is the software-pipelined (skewed) schedule: each tick
    computes from the buffer *received last tick* while ppermuting the output
    computed *last tick* — the two ops have no data dependence inside a tick,
    so the compiler's latency-hiding scheduler can run the collective
    concurrently with stage compute. The price is one extra tick of skew per
    stage boundary (ticks = nm + 2(k-1) instead of nm + k-1): microbatch j
    reaches stage s at tick j + 2s. Per-microbatch compute is identical, so
    losses/grads match the oracle bit-for-bit."""
    stages = cfg.stages
    si = jax.lax.axis_index(S_AX)
    Bl, S, d = x_local.shape
    mb = Bl // nm
    x_wave = x_local.reshape(nm, mb, S, d)
    meta_arrs = {k: meta_local[k][0] for k in
                 ("kind", "valid", "full_i", "win_i")}          # [slots]
    skew = 2 if overlap else 1
    ticks = nm + skew * (stages - 1)
    perm = [(i, i + 1) for i in range(stages - 1)]

    def stage_call(x_in, cache_mb, tick_valid, pos_, lens_=None):
        ctx = LayerCtx(mode=mode, pos=pos_, tp_axis=tp_axis,
                       merge_axis=merge_axis, seq_offset=seq_offset,
                       valid=tick_valid, lens=lens_,
                       kernel_backend=kernel_backend)
        return _stage_apply(cfg, blocks_local, x_in, meta_arrs, ctx, cache_mb)

    stage_fn = jax.checkpoint(stage_call) if (remat and mode == "train") \
        else stage_call

    def tick(carry, t):
        if overlap:
            buf_in, y_send, out, cache_c, aux = carry
        else:
            buf_in, out, cache_c, aux = carry
            y_send = None
        mb_idx = t - skew * si
        valid = (mb_idx >= 0) & (mb_idx < nm)
        mb_c = jnp.clip(mb_idx, 0, nm - 1)
        x_fresh = jax.lax.dynamic_index_in_dim(x_wave, mb_c, 0, keepdims=False)
        x_in = jnp.where(si == 0, x_fresh, buf_in)
        # per-row decode positions / prompt lengths ([Bl] vectors) slice
        # with the microbatch, like the cache; a scalar pos is shared
        pos_mb = (jax.lax.dynamic_slice_in_dim(pos, mb_c * mb, mb)
                  if pos is not None and jnp.ndim(pos) == 1 else pos)
        lens_mb = (jax.lax.dynamic_slice_in_dim(lens, mb_c * mb, mb)
                   if lens is not None else None)
        if cache_c is None:
            y, _, aux_t = stage_fn(x_in, None, valid, pos_=pos_mb,
                                   lens_=lens_mb)
        else:
            # serve path (no AD): bubble ticks skip the cache read/write and
            # the stage compute entirely — otherwise every dead tick pays the
            # full cache-slice HBM traffic ((nm+k-1)/nm x minimal bytes;
            # measured 2.9x for decode_32k at nm=8 — EXPERIMENTS.md §Perf)
            def live(cc):
                cm = cache_lib.slice_mb(cc, mb_c, mb)
                y_, new_cm, a_ = stage_fn(x_in, cm, valid, pos_=pos_mb,
                                          lens_=lens_mb)
                cc = cache_lib.update_mb(cc, new_cm, mb_c, mb, valid)
                return cc, y_, a_

            def dead(cc):
                return cc, jnp.zeros_like(x_in), jnp.zeros((), jnp.float32)

            cache_c, y, aux_t = jax.lax.cond(valid, live, dead, cache_c)
        aux = aux + jnp.where(valid, aux_t, 0.0)
        out_idx = t - skew * (stages - 1)
        w_valid = (si == stages - 1) & (out_idx >= 0) & (out_idx < nm)
        oc = jnp.clip(out_idx, 0, nm - 1)
        old = jax.lax.dynamic_index_in_dim(out, oc, 0, keepdims=False)
        out = jax.lax.dynamic_update_index_in_dim(
            out, jnp.where(w_valid, y, old), oc, 0)
        if overlap:
            # double-buffered carry: send last tick's output (no dependence
            # on this tick's stage_fn, so the transfer overlaps the compute);
            # it is consumed by the next stage one tick after arrival, i.e.
            # two ticks after it was computed — matching the 2-tick skew.
            buf_next = jax.lax.ppermute(y_send, S_AX, perm)
            return (buf_next, y, out, cache_c, aux), None
        buf_next = jax.lax.ppermute(y, S_AX, perm)
        return (buf_next, out, cache_c, aux), None

    buf0 = jnp.zeros((mb, S, d), x_local.dtype)
    out0 = jnp.zeros_like(x_wave)
    # shape-(1,) carry: a rank-0 float carry becomes a scalar shard_map
    # residual, which jax 0.4.x partial-eval mis-names ({0: axes} on rank 0)
    aux0 = jnp.zeros((1,), jnp.float32)
    carry0 = ((buf0, jnp.zeros_like(buf0), out0, cache_local, aux0)
              if overlap else (buf0, out0, cache_local, aux0))
    final_carry, _ = jax.lax.scan(tick, carry0, jnp.arange(ticks))
    out, cache_local, aux = final_carry[-3], final_carry[-2], final_carry[-1]
    return out.reshape(Bl, S, d), cache_local, aux[0]


# ----------------------------------------------------------------------------
# spec assembly
# ----------------------------------------------------------------------------
def _meta_tree(cfg: ArchConfig):
    m = lm.layer_meta(cfg)
    arrs = {k: jnp.asarray(m[k]) for k in ("kind", "valid", "full_i", "win_i")}
    specs = {k: P(S_AX, None) for k in arrs}
    return arrs, specs


def _cast_tree(tree, dtype):
    return jax.tree.map(
        lambda a: a.astype(dtype) if a.dtype == jnp.float32 else a, tree)


def _loss_over_wave(cfg, run, params, hid, labels):
    """hid [B, S, d] paired row-for-row with labels [B, S]."""
    h = lm.final_hidden_norm(cfg, params, hid)
    return chunked_cross_entropy(
        h, lm.head_matrix(cfg, params), labels,
        chunk=min(run.loss_chunk, h.shape[-2]))


# ----------------------------------------------------------------------------
# train step
# ----------------------------------------------------------------------------
def build_train_step(run: RunConfig, mesh: Mesh):
    """Returns (train_step, state_specs) where
    train_step(params, opt_state, batch{'inputs','labels'}) ->
        (params, opt_state, metrics)."""
    cfg = run.arch
    assert cfg.stages == mesh.shape[S_AX], (cfg.stages, dict(mesh.shape))
    assert cfg.tp in (1, mesh.shape[T_AX]), (cfg.tp, dict(mesh.shape))
    nm = cfg.num_microbatches
    meta_arrs, meta_specs = _meta_tree(cfg)
    pspecs = lm.param_specs(cfg)
    tp_axis = T_AX if cfg.tp > 1 else None
    cdt = jnp.bfloat16 if run.compute_dtype == "bfloat16" else jnp.float32
    opt = make_optimizer(run.optimizer, run.lr, run.weight_decay)
    dp = dp_axes(mesh)

    def body(blocks, x, meta):
        y, _, aux = pipeline_wave(
            cfg, blocks, x, meta, mode="train", nm=nm, tp_axis=tp_axis,
            merge_axis=None, remat=cfg.remat, overlap=run.overlap)
        aux = jax.lax.psum(aux, S_AX)      # each stage holds its layers' aux
        for ax in dp:                      # aux differs per VW's tokens
            aux = jax.lax.pmean(aux, ax)
        # the CE head is vocab-sharded over (stage, tp): every model device
        # needs the final hidden anyway, so this masked psum doubles as the
        # hidden broadcast GSPMD would otherwise insert for the loss.
        return _bcast_from_last(y, cfg.stages), aux / nm

    pipe = shard_map(
        body, mesh=mesh,
        in_specs=(pspecs["blocks"], P(dp, None, None), meta_specs),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )

    def wave_loss(params, inputs, labels):
        x = lm.embed_tokens(cfg, params, inputs).astype(cdt)
        y, aux = pipe(_cast_tree(params["blocks"], cdt), x, meta_arrs)
        loss = _loss_over_wave(cfg, run, params, y, labels)
        total = loss + 0.01 * aux / max(cfg.num_layers, 1)
        return total, (total, aux)

    def train_step(params, opt_state, batch):
        (_, (loss, aux)), grads = jax.value_and_grad(
            wave_loss, has_aux=True)(params, batch["inputs"], batch["labels"])
        deltas, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(jnp.add, params, deltas)
        return params, opt_state, {"loss": loss, "aux": aux}

    state_specs = {"params": pspecs,
                   "batch": {"inputs": P(dp, *([None] * (2 if cfg.frontend ==
                                                "none" else 3))[1:]),
                             "labels": P(dp, None)},
                   "opt": None, "meta": meta_arrs, "optimizer": opt}
    return train_step, state_specs


# NOTE on out_specs of the pipeline: the per-device output y [Bl, S, d] is
# only meaningful on the last stage; out_specs P(dp, None, None) declares it
# replicated over stage/tp, and check_vma=False lets XLA pick the last stage's
# copy... which is NOT guaranteed. We therefore broadcast the last stage's
# value inside the body — see _bcast_from_last below, applied in pipeline_wave
# callers via _finalize_out.


def _bcast_from_last(y, stages):
    """Make y consistent across stages: everyone gets the last stage's copy
    via a single ppermute hop ring (last -> all through rotation is O(k) hops;
    instead use psum of masked value — one all-reduce over the stage axis)."""
    si = jax.lax.axis_index(S_AX)
    contrib = jnp.where(si == stages - 1, y, jnp.zeros_like(y))
    return jax.lax.psum(contrib, S_AX)


# ----------------------------------------------------------------------------
# serve steps (prefill / decode)
# ----------------------------------------------------------------------------
def _serve_nm(run: RunConfig, mesh) -> tuple[int, int]:
    cfg, shp = run.arch, run.shape
    vw_b = max(1, shp.global_batch // n_dp(mesh))
    nm = min(cfg.num_microbatches, vw_b)
    while vw_b % nm:
        nm -= 1
    return nm, vw_b // nm


def build_decode_step(run: RunConfig, mesh: Mesh, *,
                      pos_per_row: bool = False, layout=None):
    """step(params, batch{'inputs','cache','pos'}) -> (logits, cache).

    pos_per_row=True: batch['pos'] is a [B] vector — each batch row decodes
    at its own depth (continuous batching; rows at different generation
    depths share one jitted step). Requires an unsharded batch (data=1);
    the default scalar pos is the aligned-batch fast path.

    layout: a repro.serve.cache.PageLayout — the cache pytree is the paged
    pool + block table instead of the contiguous block (full-attention K/V
    read through the table; the pool rides the pipeline scan whole)."""
    cfg, shp = run.arch, run.shape
    nm, _ = _serve_nm(run, mesh)
    meta_arrs, meta_specs = _meta_tree(cfg)
    pspecs = lm.param_specs(cfg)
    tp_axis = T_AX if cfg.tp > 1 else None
    seq_sharded = (layout is None and shp.global_batch < 16
                   and D_AX in mesh.axis_names)
    merge_axis = D_AX if seq_sharded else None
    cdt, cache_dt = lm.serve_dtypes(run.compute_dtype, run.cache_dtype)
    if layout is not None:
        _, cspecs = cache_lib.paged_struct(cfg, layout, dtype=cache_dt)
    else:
        _, cspecs = cache_lib.cache_struct(
            cfg, shp.global_batch, shp.seq_len,
            seq_shards=16 if seq_sharded else 1, dtype=cache_dt)
    dp = dp_axes(mesh) if not seq_sharded else ()
    nd = mesh.shape[D_AX] if D_AX in mesh.axis_names else 1
    if pos_per_row and n_dp(mesh) != 1:
        raise ValueError("pos_per_row decode needs the whole batch on every "
                         "data shard; use a data=1 mesh")
    if layout is not None and n_dp(mesh) != 1:
        raise ValueError("the paged pool is shared by the whole batch; "
                         "paged decode needs a data=1 mesh")
    pos_spec = P(None) if pos_per_row else P()

    def body(blocks, x, meta, cache, pos):
        so = jax.lax.axis_index(D_AX) * (shp.seq_len // nd) if seq_sharded \
            else 0
        y, cache, aux = pipeline_wave(
            cfg, blocks, x, meta, mode="decode", nm=nm, cache_local=cache,
            pos=pos, tp_axis=tp_axis, merge_axis=merge_axis, seq_offset=so,
            overlap=run.overlap, kernel_backend=run.kernel_backend)
        return _bcast_from_last(y, cfg.stages), cache, aux

    pipe = shard_map(
        body, mesh=mesh,
        in_specs=(pspecs["blocks"], P(dp, None, None), meta_specs, cspecs,
                  pos_spec),
        out_specs=(P(dp, None, None), cspecs, P()),
        check_vma=False,
    )

    def decode_step(params, batch):
        x = lm.embed_tokens(cfg, params, batch["inputs"]).astype(cdt)
        logits_hid, cache, _ = pipe(_cast_tree(params["blocks"], cdt), x,
                                    meta_arrs, batch["cache"], batch["pos"])
        logits = lm.logits_ref(cfg, params, logits_hid)
        return logits, cache

    return decode_step, pspecs, cspecs


def build_prefill_step(run: RunConfig, mesh: Mesh, *, cache_len: int = 0,
                       layout=None, var_len: bool = False):
    """step(params, batch{'inputs','cache'[,'lens']}) -> (last_logits, cache).

    cache_len > shp.seq_len sizes the cache for the decode phase that
    follows prefill (serve: prompt_len inputs, prompt_len + gen cache slots;
    the prefill write zero-pads the unwritten tail).

    layout: PageLayout — prefill scatters K/V page-granularly through
    batch['cache']'s block table instead of filling contiguous rows.
    var_len=True: batch['lens'] is a [B] vector of per-row prompt lengths
    (right-padded prompts); cache writes stop at each row's length and the
    returned logits are each row's *last real* position."""
    cfg, shp = run.arch, run.shape
    nm, _ = _serve_nm(run, mesh)
    meta_arrs, meta_specs = _meta_tree(cfg)
    pspecs = lm.param_specs(cfg)
    tp_axis = T_AX if cfg.tp > 1 else None
    cdt, cache_dt = lm.serve_dtypes(run.compute_dtype, run.cache_dtype)
    if layout is not None:
        _, cspecs = cache_lib.paged_struct(cfg, layout, dtype=cache_dt)
    else:
        _, cspecs = cache_lib.cache_struct(cfg, shp.global_batch,
                                           cache_len or shp.seq_len,
                                           dtype=cache_dt)
    if (layout is not None or var_len) and n_dp(mesh) != 1:
        # mirrors build_decode_step: the paged pool (and the per-row lens
        # vector) address the whole batch; a data-sharded x would pair
        # shard-local rows with global lens/table rows silently
        raise ValueError("paged / variable-length prefill needs the whole "
                         "batch on every data shard; use a data=1 mesh")
    dp = dp_axes(mesh)

    def body(blocks, x, meta, cache, lens=None):
        y, cache, aux = pipeline_wave(
            cfg, blocks, x, meta, mode="prefill", nm=nm, cache_local=cache,
            pos=None, lens=lens, tp_axis=tp_axis, merge_axis=None,
            overlap=run.overlap, kernel_backend=run.kernel_backend)
        if lens is None:
            last = y[:, -1:]
        else:
            last = jnp.take_along_axis(
                y, jnp.maximum(lens - 1, 0)[:, None, None], axis=1)
        return _bcast_from_last(last, cfg.stages), cache, aux

    in_specs = [pspecs["blocks"], P(dp, None, None), meta_specs, cspecs]
    if var_len:
        in_specs.append(P(None))
    pipe = shard_map(
        body, mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(dp, None, None), cspecs, P()),
        check_vma=False,
    )

    def prefill_step(params, batch):
        x = lm.embed_tokens(cfg, params, batch["inputs"]).astype(cdt)
        args = (_cast_tree(params["blocks"], cdt), x, meta_arrs,
                batch["cache"])
        if var_len:
            args += (batch["lens"],)
        last_hid, cache, _ = pipe(*args)
        logits = lm.logits_ref(cfg, params, last_hid)
        return logits, cache

    return prefill_step, pspecs, cspecs


# ----------------------------------------------------------------------------
# single-device wave step (per-VW; used by the threaded WSP runtime and as
# the pipeline-correctness oracle: a wave == grad accumulation over Nm
# minibatches computed with wave-start weights)
# ----------------------------------------------------------------------------
def build_local_wave_step(cfg: ArchConfig, nm: int, optimizer):
    def wave_loss(params, inputs, labels):
        def mb_loss(carry, xs):
            x_mb, l_mb = xs
            loss, _, _ = lm.forward_ref(cfg, params, x_mb, mode="train",
                                        labels=l_mb)
            return carry + loss, None
        B = labels.shape[0]
        xw = inputs.reshape(nm, B // nm, *inputs.shape[1:])
        lw = labels.reshape(nm, B // nm, labels.shape[1])
        total, _ = jax.lax.scan(mb_loss, jnp.zeros((), jnp.float32), (xw, lw))
        return total / nm

    @jax.jit
    def wave_step(params, opt_state, inputs, labels):
        loss, grads = jax.value_and_grad(wave_loss)(params, inputs, labels)
        deltas, opt_state = optimizer.update(grads, opt_state, params)
        return deltas, opt_state, loss

    return wave_step
