"""End-to-end training driver.

Two modes:
  --mode spmd    one jitted pipelined wave step over a (data, stage, tp) mesh
                 (WSP D=0; the production path — on CPU use a small mesh via
                 --devices, which must be set before jax initializes, so this
                 mode re-execs itself with XLA_FLAGS when needed)
  --mode wsp     threaded multi-VW WSP runtime with the parameter server
                 (true async D>=0, stragglers, checkpoint/restart, elastic)

Example (CPU, reduced model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --mode wsp \
      --reduced --waves 50 --num-vw 4 --D 2
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--mode", choices=("spmd", "wsp"), default="wsp")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--waves", type=int, default=50)
    ap.add_argument("--num-vw", type=int, default=4)
    ap.add_argument("--D", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", type=float, default=None)
    ap.add_argument("--codec", default=None,
                    help="gradient codec: topk:<ratio> | int8 | none")
    ap.add_argument("--topology", default=None,
                    help="network model: single | <k>node[:ib] | "
                         "hetero-2node | paper (default: zero-latency)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="scale modeled network delays before sleeping")
    ap.add_argument("--overlap", action="store_true",
                    help="overlap communication with compute: wsp mode pushes "
                         "wave deltas asynchronously (next wave's forward "
                         "starts while the push is in flight); spmd mode uses "
                         "the software-pipelined (skewed) schedule so the "
                         "boundary ppermute runs concurrently with stage "
                         "compute")
    ap.add_argument("--pull-every", type=int, default=1,
                    help="wsp mode: pull w_global every k waves (local delta "
                         "updates in between; k>1 lets async pushes overlap)")
    ap.add_argument("--speeds", default=None,
                    help="comma-separated per-VW slowdowns (s/wave)")
    ap.add_argument("--devices", type=int, default=0,
                    help="spmd mode: fake host device count (data*stage*tp)")
    ap.add_argument("--mesh", default="2,2,2",
                    help="spmd mode: data,stage,tp")
    a = ap.parse_args()

    if a.mode == "spmd" and a.devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={a.devices}"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import ARCHS, reduced as make_reduced, RunConfig, \
        ShapeConfig
    from repro.models import lm
    from repro.optim import make_optimizer
    from repro.core import wave

    cfg = ARCHS[a.arch]
    if a.reduced:
        dm, st, tp = a.d_model, 2, 1
        heads = max(1, min(cfg.num_heads, 4)) if cfg.num_heads else 0
        cfg = make_reduced(cfg, d_model=dm, d_ff=2 * dm, num_layers=a.layers,
                           vocab_size=256, stages=st, tp=tp,
                           num_heads=heads,
                           num_kv_heads=max(1, heads // 2) if heads else 0,
                           head_dim=dm // heads if heads else 0)
    params, pspecs = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(a.optimizer, a.lr)
    print(f"arch={cfg.name} params={sum(np.size(x) for x in jax.tree.leaves(params)):,}")

    if a.mode == "wsp":
        from repro.runtime.trainer import WSPTrainer
        if a.overlap and a.pull_every == 1:
            print("note: --overlap with --pull-every 1 serializes every push "
                  "behind the following pull (each wave starts from freshly "
                  "pulled weights); use --pull-every > 1 to actually hide "
                  "push latency", file=sys.stderr)
        from repro.runtime.checkpoint import latest_checkpoint, \
            load_checkpoint
        step = wave.build_local_wave_step(cfg, cfg.num_microbatches, opt)
        if a.resume and a.ckpt_dir:
            path = latest_checkpoint(a.ckpt_dir)
            if path:
                out, meta = load_checkpoint(path, {"params": params})
                params = out["params"]
                print(f"resumed from {path} (step {meta['step']})")
        speeds = ([float(s) for s in a.speeds.split(",")]
                  if a.speeds else None)
        tr = WSPTrainer(params, step, opt, num_vw=a.num_vw, D=a.D,
                        batch=a.batch, seq=a.seq, vocab=cfg.vocab_size,
                        max_waves=a.waves, speeds=speeds,
                        compression_ratio=a.compression,
                        codec=a.codec, topology=a.topology,
                        time_scale=a.time_scale,
                        pull_every=a.pull_every, async_push=a.overlap,
                        ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every)
        rep = tr.run()
        xs, ys = rep.loss_curve()
        print(f"waves={rep.waves} wall={rep.wall_s:.1f}s "
              f"first_loss={ys[0]:.4f} last_loss={np.mean(ys[-5:]):.4f}")
        if a.overlap:
            print(f"overlap: hidden={rep.overlap_seconds:.2f}s "
                  f"blocked={rep.push_wait_seconds:.2f}s")
        print(f"pushed={rep.bytes_pushed/1e6:.1f}MB wire="
              f"{rep.bytes_wire/1e6:.1f}MB waits={ {k: round(v,2) for k, v in rep.wait_seconds.items()} }")
        if tr.topology is not None:
            by_link = rep.comm.get("bytes_by_link", {})
            print(f"network: modeled={rep.comm_seconds:.2f}s "
                  f"bytes_by_link={ {k: f'{v/1e6:.1f}MB' for k, v in by_link.items()} }")
        return

    # spmd mode
    if a.topology or a.codec or a.compression:
        print("warning: --topology/--codec/--compression only apply to "
              "--mode wsp; ignored in spmd mode", file=sys.stderr)
    from jax.sharding import NamedSharding, PartitionSpec as P
    dsz, ssz, tsz = (int(x) for x in a.mesh.split(","))
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((dsz, ssz, tsz), ("data", "stage", "tp"))
    import dataclasses
    cfg = dataclasses.replace(cfg, stages=ssz, tp=tsz)
    params, pspecs = lm.init_params(cfg, jax.random.PRNGKey(0))
    shape = ShapeConfig("cli", a.seq, a.batch * dsz, "train")
    run = RunConfig(arch=cfg, shape=shape, optimizer=a.optimizer, lr=a.lr,
                    compute_dtype="float32", loss_chunk=min(512, a.seq),
                    overlap=a.overlap)
    step, _ = wave.build_train_step(run, mesh)
    from repro.data.pipeline import MarkovLM, ShardedLoader
    loader = ShardedLoader(MarkovLM(cfg.vocab_size), shape.global_batch,
                           a.seq, 0, 1)
    from repro.compat import set_mesh
    with set_mesh(mesh):
        p_sh = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: isinstance(x, P)))
        opt_state = opt.init(p_sh)
        jstep = jax.jit(step, donate_argnums=(0, 1))
        for w in range(a.waves):
            x, y = loader.next()
            t0 = time.time()
            p_sh, opt_state, m = jstep(p_sh, opt_state,
                                       {"inputs": jnp.asarray(x),
                                        "labels": jnp.asarray(y)})
            if w % 5 == 0 or w == a.waves - 1:
                print(f"wave {w:4d} loss={float(m['loss']):.4f} "
                      f"({time.time()-t0:.2f}s)")


if __name__ == "__main__":
    main()
