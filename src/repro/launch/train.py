"""End-to-end training driver — a thin CLI over the repro.api layer.

Both modes build a declarative `repro.api.Plan` and run it through the same
`Engine`:
  --mode spmd    one jitted pipelined wave step over a (data, stage, tp) mesh
                 (WSP D=0; the production path — on CPU use a small mesh via
                 --devices, which must be set before jax initializes, so this
                 mode re-execs itself with XLA_FLAGS when needed)
  --mode wsp     threaded multi-VW WSP runtime with the parameter server
                 (true async D>=0, stragglers, checkpoint/restart, elastic)

Example (CPU, reduced model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --mode wsp \
      --reduced --waves 50 --num-vw 4 --D 2
"""
from __future__ import annotations

import argparse
import os
import sys


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--mode", choices=("spmd", "wsp"), default="wsp")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--waves", type=int, default=50)
    ap.add_argument("--num-vw", type=int, default=4)
    ap.add_argument("--D", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compression", type=float, default=None)
    ap.add_argument("--codec", default=None,
                    help="gradient codec: topk:<ratio> | int8 | none")
    ap.add_argument("--topology", default=None,
                    help="network model spec, or 'list' to print every "
                         "accepted spec and exit (default: zero-latency)")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="scale modeled network delays before sleeping")
    ap.add_argument("--overlap", action="store_true",
                    help="overlap communication with compute: wsp mode pushes "
                         "wave deltas asynchronously (next wave's forward "
                         "starts while the push is in flight); spmd mode uses "
                         "the software-pipelined (skewed) schedule so the "
                         "boundary ppermute runs concurrently with stage "
                         "compute")
    ap.add_argument("--pull-every", type=int, default=1,
                    help="wsp mode: pull w_global every k waves (local delta "
                         "updates in between; k>1 lets async pushes overlap)")
    ap.add_argument("--speeds", default=None,
                    help="comma-separated per-VW slowdowns (s/wave)")
    ap.add_argument("--devices", type=int, default=0,
                    help="spmd mode: fake host device count (data*stage*tp)")
    ap.add_argument("--mesh", default="2,2,2",
                    help="spmd mode: data,stage,tp")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace-event JSON (Perfetto-"
                         "loadable) of the run, with the metrics snapshot "
                         "embedded; inspect with python -m repro.obs.summary")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="wsp mode: inject the seeded random fault scenario "
                         "FaultPlan.sample_train(SEED) — a worker crash, a "
                         "link outage on a push path, a slowdown onset and a "
                         "PS stall — with eviction + rejoin recovery "
                         "enabled; prints the run's fault digest")
    return ap


def main(argv=None):
    a = build_parser().parse_args(argv)

    if a.topology == "list":
        from repro.dist.topology import topology_help
        print("accepted --topology specs:")
        print(topology_help())
        return

    # the re-exec trick only makes sense for a real CLI invocation: sys.argv
    # is this process's own command line. A programmatic caller passing argv
    # must set XLA_FLAGS itself (the Engine's device check says how).
    if a.mode == "spmd" and a.devices and argv is None \
            and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={a.devices}"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax
    import numpy as np

    from repro.api import ClusterSpec, Engine, PartitionSpec, Plan, \
        RunSpec, WSP
    from repro.configs import ARCHS, reduced as make_reduced
    from repro.obs import NULL_TRACER, Tracer

    tracer = Tracer() if a.trace else NULL_TRACER

    cfg = ARCHS[a.arch]
    if a.reduced:
        dm, st, tp = a.d_model, 2, 1
        heads = max(1, min(cfg.num_heads, 4)) if cfg.num_heads else 0
        cfg = make_reduced(cfg, d_model=dm, d_ff=2 * dm, num_layers=a.layers,
                           vocab_size=256, stages=st, tp=tp,
                           num_heads=heads,
                           num_kv_heads=max(1, heads // 2) if heads else 0,
                           head_dim=dm // heads if heads else 0)
    print(f"arch={cfg.name} params={cfg.param_count():,} (analytic)")

    if a.mode == "wsp":
        if a.overlap and a.pull_every == 1:
            print("note: --overlap with --pull-every 1 serializes every push "
                  "behind the following pull (each wave starts from freshly "
                  "pulled weights); use --pull-every > 1 to actually hide "
                  "push latency", file=sys.stderr)
        speeds = ([float(s) for s in a.speeds.split(",")]
                  if a.speeds else None)
        fault_kwargs = {}
        if a.chaos is not None:
            from repro.api import FaultPlan, FaultPolicy
            faults = FaultPlan.sample_train(a.chaos, num_vw=a.num_vw,
                                            max_waves=a.waves)
            fault_kwargs = dict(
                faults=faults,
                fault_policy=FaultPolicy(evict_lag=1, rejoin_after_waves=1,
                                         allow_degraded=True))
            print(f"chaos: {faults.describe()}")
        plan = Plan(
            arch=cfg,
            cluster=ClusterSpec(num_vw=a.num_vw, topology=a.topology,
                                speeds=speeds, time_scale=a.time_scale),
            sync=WSP(D=a.D, pull_every=a.pull_every, async_push=a.overlap),
            run=RunSpec(max_waves=a.waves, batch=a.batch, seq=a.seq,
                        optimizer=a.optimizer, lr=a.lr,
                        compression_ratio=a.compression, codec=a.codec,
                        ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every,
                        resume=a.resume),
            **fault_kwargs)
        eng = Engine(plan, tracer=tracer)
        rep = eng.fit()
        if a.chaos is not None:
            print(f"faults: {rep.fault_digest()}")
        if a.trace:
            print(f"trace: {tracer.export(a.trace)}")
        xs, ys = rep.loss_curve()
        print(f"waves={rep.waves} wall={rep.wall_s:.1f}s "
              f"first_loss={ys[0]:.4f} last_loss={np.mean(ys[-5:]):.4f}")
        if a.overlap:
            print(f"overlap: hidden={rep.overlap_seconds:.2f}s "
                  f"blocked={rep.push_wait_seconds:.2f}s")
        print(f"pushed={rep.bytes_pushed/1e6:.1f}MB wire="
              f"{rep.bytes_wire/1e6:.1f}MB waits={ {k: round(v,2) for k, v in rep.wait_seconds.items()} }")
        if eng.topology is not None:
            by_link = rep.comm.get("bytes_by_link", {})
            print(f"network: modeled={rep.comm_seconds:.2f}s "
                  f"bytes_by_link={ {k: f'{v/1e6:.1f}MB' for k, v in by_link.items()} }")
        return

    # spmd mode
    if a.chaos is not None:
        raise SystemExit("--chaos needs the threaded WSP runtime; "
                         "use --mode wsp")
    if a.topology or a.codec or a.compression:
        print("warning: --topology/--codec/--compression only apply to "
              "--mode wsp; ignored in spmd mode", file=sys.stderr)
    dsz, ssz, tsz = (int(x) for x in a.mesh.split(","))
    plan = Plan(
        arch=cfg,
        partition=PartitionSpec(data=dsz, stages=ssz, tp=tsz),
        sync=WSP(D=0),
        run=RunSpec(backend="spmd", max_waves=a.waves, batch=a.batch,
                    seq=a.seq, optimizer=a.optimizer, lr=a.lr,
                    overlap=a.overlap, resume=a.resume,
                    ckpt_dir=a.ckpt_dir,
                    ckpt_every=a.ckpt_every if a.ckpt_dir else 0))
    eng = Engine(plan, tracer=tracer)
    n_dev = len(jax.devices())
    print(f"mesh=({dsz},{ssz},{tsz}) devices={n_dev}")

    def log(w, loss, dt):
        if w % 5 == 0 or w == a.waves - 1:
            print(f"wave {w:4d} loss={loss:.4f} ({dt:.2f}s)")

    eng.fit(callback=log)
    if a.trace:
        print(f"trace: {tracer.export(a.trace)}")


if __name__ == "__main__":
    main()
