"""Production meshes.

make_production_mesh() builds the required (data=16, model=16) single-pod /
(pod=2, data=16, model=16) multi-pod mesh. Architectures factor the model axis
into stage x tp; make_logical_mesh() re-views the SAME device order with the
model axis split — tp groups are ICI-adjacent (innermost), stages next, so
high-traffic TP collectives ride the fastest links.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

AUTO = getattr(jax.sharding, "AxisType", None)


def make_mesh_auto(shape, names):
    """jax.make_mesh with Auto axis types when this jax version has them
    (axis_types landed after 0.4.x; older versions are Auto-only anyway)."""
    kw = {}
    if AUTO is not None:
        kw["axis_types"] = (AUTO.Auto,) * len(names)
    return jax.make_mesh(shape, names, **kw)


_make = make_mesh_auto


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_logical_mesh(prod: Mesh, stages: int, tp: int) -> Mesh:
    """Split the production mesh's 16-wide `model` axis into (stage, tp),
    preserving physical device order (tp innermost = ICI-adjacent)."""
    model = prod.shape["model"]
    assert stages * tp == model, (stages, tp, model)
    names = list(prod.axis_names)
    devs = np.asarray(prod.devices)
    new_shape = devs.shape[:-1] + (stages, tp)
    new_names = tuple(names[:-1]) + ("stage", "tp")
    kw = {}
    if AUTO is not None:
        kw["axis_types"] = (AUTO.Auto,) * len(new_names)
    return Mesh(devs.reshape(new_shape), new_names, **kw)


def make_test_mesh(data=2, stages=2, tp=2) -> Mesh:
    """Small logical mesh for CPU multi-device tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=data*stages*tp)."""
    return _make((data, stages, tp), ("data", "stage", "tp"))
