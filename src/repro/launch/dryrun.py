import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production mesh (16x16 single-pod and 2x16x16 multi-pod) with
ShapeDtypeStruct stand-ins (no allocation), and record memory / cost /
collective analyses for the roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  PYTHONPATH=src:. python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src:. python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, RunConfig, cell_is_runnable
from repro.core import wave
from repro.launch.mesh import make_production_mesh, make_logical_mesh
from repro.models import lm

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _eval_shape_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def build_cell(arch_name: str, shape_name: str, multi_pod: bool,
               run_overrides: dict | None = None):
    """Returns (jitted-unlowered fn, example args (ShapeDtypeStructs),
    in_shardings, mesh)."""
    import dataclasses
    cfg = ARCHS[arch_name]
    shp = SHAPES[shape_name]
    run = RunConfig(arch=cfg, shape=shp, multi_pod=multi_pod)
    if run_overrides:
        run = dataclasses.replace(run, **run_overrides)
    prod = make_production_mesh(multi_pod=multi_pod)
    mesh = make_logical_mesh(prod, cfg.stages, cfg.tp)
    dp = wave.dp_axes(mesh)

    params_s = lm.param_shapes(cfg)
    pspecs = lm.param_specs(cfg)
    ins = lm.input_specs(run)

    if shp.kind == "train":
        step, sp = wave.build_train_step(run, mesh)
        opt = sp["optimizer"]
        opt_s = jax.eval_shape(opt.init, params_s)
        opt_specs = jax.tree.map(lambda _: P(), opt_s)
        opt_specs = {"m": pspecs, "v": pspecs,
                     "step": P()} if "v" in opt_s else (
            {"m": pspecs, "step": P()} if "m" in opt_s else {"step": P()})
        batch = {"inputs": ins["inputs"], "labels": ins["labels"]}
        b_specs = {"inputs": P(dp, *((None,) * (len(ins["inputs"].shape) - 1))),
                   "labels": P(dp, None)}
        args = (params_s, opt_s, batch)
        shardings = (_ns(mesh, pspecs), _ns(mesh, opt_specs),
                     _ns(mesh, b_specs))
        fn = jax.jit(step, in_shardings=shardings, donate_argnums=(0, 1))
        return fn, args, mesh, run

    if shp.kind == "prefill":
        step, pspecs2, cspecs = wave.build_prefill_step(run, mesh)
        batch = {"inputs": ins["inputs"], "cache": ins["cache"]}
        b_specs = {"inputs": P(dp, *((None,) * (len(ins["inputs"].shape) - 1))),
                   "cache": cspecs}
        args = (params_s, batch)
        fn = jax.jit(step, in_shardings=(_ns(mesh, pspecs2),
                                         _ns(mesh, b_specs)),
                     donate_argnums=(1,))
        return fn, args, mesh, run

    step, pspecs2, cspecs = wave.build_decode_step(run, mesh)
    seq_sharded = shp.global_batch < 16
    bspec_in = P(dp if not seq_sharded else None,
                 *((None,) * (len(ins["inputs"].shape) - 1)))
    batch = {"inputs": ins["inputs"], "cache": ins["cache"],
             "pos": ins["pos"]}
    b_specs = {"inputs": bspec_in, "cache": cspecs, "pos": P()}
    args = (params_s, batch)
    fn = jax.jit(step, in_shardings=(_ns(mesh, pspecs2), _ns(mesh, b_specs)),
                 donate_argnums=(1,))
    return fn, args, mesh, run


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             force: bool = False, save: bool = True) -> dict:
    tag = f"{arch_name}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    os.makedirs(ART_DIR, exist_ok=True)
    path = os.path.join(ART_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    rec = {"cell": tag, "arch": arch_name, "shape": shape_name,
           "multi_pod": multi_pod, "ok": False}
    if not cell_is_runnable(ARCHS[arch_name], shape_name):
        rec.update(skipped=True, reason="long_500k on full-attention arch "
                   "(per assignment; see DESIGN.md §Arch-applicability)")
        rec["ok"] = True
        if save:
            json.dump(rec, open(path, "w"), indent=1)
        return rec
    try:
        t0 = time.time()
        fn, args, mesh, run = build_cell(arch_name, shape_name, multi_pod)
        from benchmarks.jaxpr_analysis import analyze_fn
        with mesh:
            jc = analyze_fn(fn, args, mesh)   # trip-count-aware trace costs
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        from benchmarks.hlo_parse import collective_bytes, link_bytes
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        rec.update(
            ok=True,
            lower_s=round(t1 - t0, 2), compile_s=round(t2 - t1, 2),
            flops=float(cost.get("flops", 0.0)),
            hbm_bytes=float(cost.get("bytes accessed", 0.0)),
            memory=None if mem is None else {
                k: int(getattr(mem, k, 0)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes")},
            collectives=coll,
            link_bytes=float(link_bytes(coll)),
            trace_flops=jc.flops, trace_dot_flops=jc.dot_flops,
            trace_bytes_upper=jc.bytes_upper, trace_dot_bytes=jc.dot_bytes,
            trace_collectives={k: float(v)
                               for k, v in jc.collective_bytes.items()},
            trace_link_bytes=float(jc.link_bytes),
            hlo_ops=len(hlo.splitlines()),
            params=ARCHS[arch_name].param_count(),
            active_params=ARCHS[arch_name].active_param_count(),
            stages=run.arch.stages, tp=run.arch.tp,
            nm=run.arch.num_microbatches,
        )
    except Exception as e:  # noqa: BLE001 — record the failure verbatim
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if save:
        json.dump(rec, open(path, "w"), indent=1)
    return rec


def retrace_cell(arch_name: str, shape_name: str, multi_pod: bool):
    """Recompute the trace-analysis fields of an existing artifact (fast —
    no 512-device recompile) after cost-model refinements."""
    tag = f"{arch_name}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    path = os.path.join(ART_DIR, tag + ".json")
    if not os.path.exists(path):
        return None
    rec = json.load(open(path))
    if rec.get("skipped") or not rec.get("ok"):
        return rec
    from benchmarks.jaxpr_analysis import analyze_fn
    fn, args, mesh, run = build_cell(arch_name, shape_name, multi_pod)
    with mesh:
        jc = analyze_fn(fn, args, mesh)
    rec.update(
        trace_flops=jc.flops, trace_dot_flops=jc.dot_flops,
        trace_bytes_upper=jc.bytes_upper, trace_dot_bytes=jc.dot_bytes,
        trace_collectives={k: float(v)
                           for k, v in jc.collective_bytes.items()},
        trace_link_bytes=float(jc.link_bytes),
        trace_kern_dot_bytes=float(jc.kern_dot_bytes),
        trace_kern_dot_flops=float(jc.kern_dot_flops),
        trace_bytes_by_prim={k: float(v) for k, v in sorted(
            jc.bytes_by_prim.items(), key=lambda kv: -kv[1])[:10]},
    )
    json.dump(rec, open(path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--retrace", action="store_true")
    a = ap.parse_args()
    if a.retrace:
        for arch in ([a.arch] if a.arch else list(ARCHS)):
            for shape in ([a.shape] if a.shape else list(SHAPES)):
                for mp in ([a.multi_pod] if not a.both_meshes
                           else [False, True]):
                    r = retrace_cell(arch, shape, mp)
                    if r and not r.get("skipped"):
                        print(f"[RETR] {r['cell']}")
                        sys.stdout.flush()
        return 0
    archs = [a.arch] if a.arch else list(ARCHS)
    shapes = [a.shape] if a.shape else list(SHAPES)
    meshes = [a.multi_pod] if not a.both_meshes else [False, True]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, force=a.force)
                status = ("SKIP" if rec.get("skipped")
                          else "OK" if rec["ok"] else "FAIL")
                print(f"[{status:4s}] {rec['cell']}"
                      + (f" flops={rec.get('flops', 0):.3e}"
                         f" link={rec.get('link_bytes', 0):.3e}"
                         f" compile={rec.get('compile_s', 0)}s"
                         if rec.get("ok") and not rec.get("skipped") else
                         f" {rec.get('error', '')[:200]}"))
                sys.stdout.flush()
                n_fail += 0 if rec["ok"] else 1
    print(f"dryrun complete, failures={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
