"""Serving driver: batched prefill + autoregressive decode on a reduced model
(CPU) using the reference per-layer path, or the pipelined serve steps on a
mesh. Demonstrates the cache machinery end to end with batched requests.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
      --batch 4 --prompt-len 24 --gen 16
"""
from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    a = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, reduced as make_reduced
    from repro.models import lm, frontend

    cfg = ARCHS[a.arch]
    if a.reduced:
        cfg = make_reduced(cfg)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    S_max = a.prompt_len + a.gen
    B = a.batch
    if cfg.frontend != "none":
        prompt = frontend.stub_embeddings(cfg, key, B, a.prompt_len)
    else:
        prompt = jax.random.randint(key, (B, a.prompt_len), 0,
                                    cfg.vocab_size, dtype=jnp.int32)

    cache = lm.init_cache(cfg, B, S_max, dtype=jnp.float32)
    t0 = time.time()
    hid, cache, _ = lm.forward_ref(cfg, params, prompt, mode="prefill",
                                   cache=cache)
    logits = lm.logits_ref(cfg, params, hid[:, -1:])
    t_prefill = time.time() - t0

    @jax.jit
    def decode_one(params, cache, tok, pos):
        x = tok if cfg.frontend != "none" else tok
        hid, cache, _ = lm.forward_ref(cfg, params, x, mode="decode",
                                       cache=cache, pos=pos)
        return lm.logits_ref(cfg, params, hid), cache

    toks = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.time()
    for t in range(a.gen):
        pos = jnp.int32(a.prompt_len + t)
        if cfg.frontend != "none":
            # stub frontends embed generated ids through a fixed projection
            x = frontend.stub_embeddings(cfg, jax.random.fold_in(key, t),
                                         B, 1)
        else:
            x = tok
        lg, cache = decode_one(params, cache, x, pos)
        tok = jnp.argmax(lg[:, -1], axis=-1)[:, None]
        toks.append(tok)
    t_dec = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name} batch={B} prefill({a.prompt_len} tok)="
          f"{t_prefill*1e3:.1f}ms decode {a.gen} steps="
          f"{t_dec*1e3:.1f}ms ({t_dec/a.gen*1e3:.1f} ms/tok)")
    print("generated ids[0]:", out[0].tolist())


if __name__ == "__main__":
    main()
