"""Serving driver — a thin CLI over the repro.api serve surface.

Builds a serve-mode Plan (Plan.serve = ServeSpec) and runs it through the
same Engine the training drivers use:

  --backend threads   the non-pipelined forward_ref cache path (CPU oracle)
  --backend spmd      the pipelined prefill/decode steps on a
                      (1, stages, tp) mesh (re-execs with XLA_FLAGS when
                      --devices asks for fake CPU devices)

By default one aligned batch runs through Engine.generate(); --requests N
instead pushes N FIFO requests through the continuous-batching scheduler
(repro.api.serving) and prints per-request latency and slot occupancy.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
      --batch 4 --prompt-len 24 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 8 --batch 2 --gen 8
"""
from __future__ import annotations

import argparse
import os
import sys


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode batch slots (ServeSpec.max_batch)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", choices=("threads", "spmd"),
                    default="threads")
    ap.add_argument("--kernel-backend", choices=("ref", "interpret", "tpu"),
                    default="ref",
                    help="hot-path attention/SSM implementation "
                         "(ServeSpec.kernel_backend): 'ref' = jnp, "
                         "'interpret' = Pallas kernels executed in Python "
                         "(CPU parity), 'tpu' = compiled Mosaic kernels")
    ap.add_argument("--mesh", default="1,2,1",
                    help="spmd backend: data,stages,tp (data must be 1)")
    ap.add_argument("--devices", type=int, default=0,
                    help="spmd backend: fake host device count")
    ap.add_argument("--requests", type=int, default=0,
                    help="serve N requests through the continuous-batching "
                         "scheduler instead of one aligned batch")
    ap.add_argument("--page-size", type=int, default=0,
                    help="KV page tokens (ServeSpec.page_size; 0 = "
                         "contiguous degenerate, one page per slot)")
    ap.add_argument("--max-pages", type=int, default=0,
                    help="KV page pool size (ServeSpec.max_pages; 0 = "
                         "worst case batch * pages-per-slot)")
    ap.add_argument("--share-prefix", action="store_true",
                    help="map each request's longest indexed prompt prefix "
                         "onto refcounted shared pages (ServeSpec."
                         "share_prefix); the synthetic requests draw from "
                         "a small prompt pool so prefixes actually repeat")
    ap.add_argument("--evict", action="store_true",
                    help="reclaim cold indexed pages LRU-first under pool "
                         "pressure (ServeSpec.evict; needs --share-prefix)")
    ap.add_argument("--preempt", action="store_true",
                    help="under pool pressure, preempt an in-flight "
                         "request (fewest tokens generated, or most "
                         "deadline slack) and replay it instead of "
                         "refusing admission (ServeSpec.preempt)")
    ap.add_argument("--policy", choices=("fifo", "deadline"),
                    default="fifo",
                    help="scheduler admission policy (deadline orders the "
                         "queue by slack, FIFO among ties; the synthetic "
                         "requests get staggered deadlines so the order "
                         "actually differs from FIFO)")
    ap.add_argument("--replicas", default=None, metavar="B0,B1,...",
                    help="scale-out serving: comma-separated per-replica "
                         "decode batch sizes (e.g. '4,2,2' = one big + two "
                         "whimpy). Requests route through the Router "
                         "(repro.serve.router) instead of one Scheduler; "
                         "needs --requests, threads backend only")
    ap.add_argument("--topology", default=None, metavar="SPEC",
                    help="price the Router's dispatch with this cluster "
                         "topology's alpha-beta link costs (dist.topology "
                         "spec, e.g. 'hetero', '3node:eth1'); default: all "
                         "replicas equidistant")
    ap.add_argument("--route", choices=("least_loaded", "deadline"),
                    default="least_loaded",
                    help="Router dispatch policy: least_loaded books by "
                         "queue depth + page pressure + link cost; "
                         "deadline dispatches in slack order (and runs "
                         "each replica's scheduler in deadline mode)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace-event JSON (Perfetto-"
                         "loadable) of the run, with the metrics snapshot "
                         "embedded; inspect with python -m repro.obs.summary")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject the seeded random fault scenario "
                         "FaultPlan.sample_serve(SEED) — decode-slot faults "
                         "the scheduler recovers from by quarantine + "
                         "requeue; needs --requests")
    return ap


def main(argv=None):
    a = build_parser().parse_args(argv)

    if a.backend == "spmd" and a.devices and argv is None \
            and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={a.devices}"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax
    import numpy as np

    from repro.api import Engine, PartitionSpec, Plan, RunSpec, ServeSpec
    from repro.api.serving import Request, Scheduler
    from repro.configs import ARCHS, reduced as make_reduced

    cfg = ARCHS[a.arch]
    if a.reduced:
        cfg = make_reduced(cfg)

    if not a.requests and (a.page_size or a.max_pages
                           or a.policy != "fifo" or a.chaos is not None
                           or a.share_prefix or a.evict or a.preempt):
        raise SystemExit(
            "--page-size/--max-pages/--policy/--chaos/--share-prefix/"
            "--evict/--preempt drive the continuous-batching scheduler; "
            "the aligned generate() path keeps the contiguous reference "
            "cache and would silently drop them — add --requests N")

    replica_batches = []
    if a.replicas:
        if not a.requests:
            raise SystemExit("--replicas routes requests over a replica "
                             "fleet; add --requests N")
        if a.backend != "threads":
            raise SystemExit("--replicas is threads-backend only (the "
                             "spmd mesh serves as a single replica)")
        replica_batches = [int(x) for x in a.replicas.split(",")]

    partition = PartitionSpec()
    if a.backend == "spmd":
        dsz, ssz, tsz = (int(x) for x in a.mesh.split(","))
        partition = PartitionSpec(data=dsz, stages=ssz, tp=tsz)
    elif replica_batches:
        partition = PartitionSpec(data=len(replica_batches))
    fault_kwargs = {}
    if a.chaos is not None:
        from repro.api import FaultPlan
        if replica_batches:
            faults = FaultPlan.sample_cluster(a.chaos,
                                              replicas=len(replica_batches))
        else:
            faults = FaultPlan.sample_serve(a.chaos, max_batch=a.batch)
        fault_kwargs = dict(faults=faults)
        print(f"chaos: {faults.describe()}")
    cluster_kwargs = {}
    if a.topology:
        from repro.api import ClusterSpec
        cluster_kwargs = dict(cluster=ClusterSpec(topology=a.topology))
    replica_kwargs = {}
    if replica_batches:
        from repro.api import ReplicaSpec
        replica_kwargs = dict(replicas=tuple(
            ReplicaSpec(max_batch=b) for b in replica_batches))
    plan = Plan(arch=cfg, partition=partition,
                serve=ServeSpec(prompt_len=a.prompt_len, gen=a.gen,
                                max_batch=max(replica_batches + [a.batch]),
                                temperature=a.temperature,
                                page_size=a.page_size,
                                max_pages=a.max_pages,
                                share_prefix=a.share_prefix,
                                evict=a.evict, preempt=a.preempt,
                                kernel_backend=a.kernel_backend,
                                **replica_kwargs),
                run=RunSpec(backend=a.backend),
                **cluster_kwargs, **fault_kwargs)
    from repro.obs import NULL_TRACER, Tracer
    tracer = Tracer() if a.trace else NULL_TRACER

    if replica_batches:
        from repro.api.serving import Request
        from repro.serve.router import Router
        rng = np.random.default_rng(1)

        def deadline(i):
            if a.route != "deadline":
                return 0
            return int(a.gen * (1 + (a.requests - i)))
        if a.share_prefix:
            pool = [rng.integers(0, cfg.vocab_size, a.prompt_len,
                                 dtype=np.int32)
                    for _ in range(max(1, a.requests // 4))]
            prompt_of = lambda i: pool[i % len(pool)].copy()
        else:
            prompt_of = lambda i: rng.integers(0, cfg.vocab_size,
                                               a.prompt_len, dtype=np.int32)
        reqs = [Request(rid=i, prompt=prompt_of(i), deadline=deadline(i))
                for i in range(a.requests)]
        router = Router(plan, policy=a.route, tracer=tracer)
        rep = router.run(reqs)
        if a.trace:
            print(f"trace: {tracer.export(a.trace)}")
        occ = rep.occupancy()
        print(f"arch={cfg.name} replicas={a.replicas} route={a.route} "
              f"topology={a.topology or 'flat'} requests={a.requests} "
              f"tokens={rep.tokens_out} "
              f"throughput={rep.tokens_per_s():.1f} tok/s "
              f"occupancy={'n/a' if occ is None else f'{occ:.2f}'}")
        print(f"router: dispatches={rep.router['dispatches']} "
              f"affinity_hits={rep.router['affinity_hits']} "
              f"rebalances={rep.router['rebalances']} "
              f"rounds={rep.router['rounds']} "
              f"replica_downs={rep.router['replica_downs']} "
              f"queue_peak={rep.router['queue_depth_peak']}")
        if a.share_prefix:
            print(f"memory: prefix_hit={rep.prefix_hit_tokens} tok "
                  f"shared={rep.pages_shared} evictions={rep.evictions}")
        lat = sorted(r.latency_s for r in rep.requests)
        print(f"latency: p50={lat[len(lat) // 2] * 1e3:.1f}ms "
              f"max={lat[-1] * 1e3:.1f}ms failed={rep.failed_requests}")
        return

    eng = Engine(plan, tracer=tracer)

    if a.requests:
        rng = np.random.default_rng(1)
        # deadline policy: staggered synthetic deadlines (in decode
        # steps), tighter for later arrivals, so slack ordering visibly
        # reorders the FIFO queue
        def deadline(i):
            if a.policy != "deadline":
                return 0
            return int(a.gen * (1 + (a.requests - i)))
        if a.share_prefix:
            # draw from a small prompt pool so prefixes actually repeat
            # and the index has something to hit
            pool = [rng.integers(0, cfg.vocab_size, a.prompt_len,
                                 dtype=np.int32)
                    for _ in range(max(1, a.requests // 4))]
            prompt_of = lambda i: pool[i % len(pool)].copy()
        else:
            prompt_of = lambda i: rng.integers(0, cfg.vocab_size,
                                               a.prompt_len, dtype=np.int32)
        reqs = [Request(rid=i, prompt=prompt_of(i), deadline=deadline(i))
                for i in range(a.requests)]
        rep = Scheduler(eng, policy=a.policy).run(reqs)
        if a.trace:
            print(f"trace: {tracer.export(a.trace)}")
        if a.chaos is not None:
            retries = sum(r.retries for r in rep.requests)
            print(f"faults: slot_faults={rep.slot_faults} "
                  f"requeues={rep.requeues} reprefills={rep.reprefills} "
                  f"quarantined={rep.quarantined} retries={retries} "
                  f"shed={rep.shed} failed={rep.failed_requests}")
        occ = rep.occupancy()       # None when no decode step ran (gen=1)
        pu = rep.page_utilization()
        print(f"arch={cfg.name} backend={a.backend} requests={a.requests} "
              f"slots={a.batch} tokens={rep.tokens_out} "
              f"decode={rep.ms_per_token():.1f}ms/tok "
              f"throughput={rep.tokens_per_s():.1f} tok/s "
              f"occupancy={'n/a' if occ is None else f'{occ:.2f}'} "
              f"pages={rep.peak_pages}/{rep.pages_total}"
              f"(x{rep.page_size} tok)"
              f" util={'n/a' if pu is None else f'{pu:.2f}'}")
        if a.share_prefix or a.evict or a.preempt:
            print(f"memory: prefix_hit={rep.prefix_hit_tokens} tok "
                  f"shared={rep.pages_shared} cow={rep.cow_copies} "
                  f"evictions={rep.evictions} "
                  f"readmits={rep.readmit_recomputes} "
                  f"preemptions={rep.preemptions}")
        lat = sorted(r.latency_s for r in rep.requests)
        print(f"latency: p50={lat[len(lat) // 2] * 1e3:.1f}ms "
              f"max={lat[-1] * 1e3:.1f}ms "
              f"ttft={rep.mean_ttft() * 1e3:.1f}ms "
              f"({rep.prefill_calls} prefill groups)")
        print("generated ids[rid=0]:", rep.requests[0].tokens)
        return

    rep = eng.generate()
    if a.trace:
        print(f"trace: {tracer.export(a.trace)}")
    print(f"arch={cfg.name} backend={a.backend} batch={a.batch} "
          f"prefill({a.prompt_len} tok)={rep.prefill_s * 1e3:.1f}ms "
          f"decode {rep.decode_steps} steps={rep.decode_s * 1e3:.1f}ms "
          f"({rep.ms_per_token():.1f} ms/tok)")
    print("generated ids[0]:", np.asarray(rep.tokens)[0].tolist())


if __name__ == "__main__":
    main()
