"""Serving driver — a thin CLI over the repro.api serve surface.

Builds a serve-mode Plan (Plan.serve = ServeSpec) and runs it through the
same Engine the training drivers use:

  --backend threads   the non-pipelined forward_ref cache path (CPU oracle)
  --backend spmd      the pipelined prefill/decode steps on a
                      (1, stages, tp) mesh (re-execs with XLA_FLAGS when
                      --devices asks for fake CPU devices)

By default one aligned batch runs through Engine.generate(); --requests N
instead pushes N FIFO requests through the continuous-batching scheduler
(repro.api.serving) and prints per-request latency and slot occupancy.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
      --batch 4 --prompt-len 24 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced \
      --requests 8 --batch 2 --gen 8
"""
from __future__ import annotations

import argparse
import os
import sys


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="decode batch slots (ServeSpec.max_batch)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--backend", choices=("threads", "spmd"),
                    default="threads")
    ap.add_argument("--mesh", default="1,2,1",
                    help="spmd backend: data,stages,tp (data must be 1)")
    ap.add_argument("--devices", type=int, default=0,
                    help="spmd backend: fake host device count")
    ap.add_argument("--requests", type=int, default=0,
                    help="serve N requests through the continuous-batching "
                         "scheduler instead of one aligned batch")
    return ap


def main(argv=None):
    a = build_parser().parse_args(argv)

    if a.backend == "spmd" and a.devices and argv is None \
            and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={a.devices}"
        os.execv(sys.executable, [sys.executable] + sys.argv)

    import jax
    import numpy as np

    from repro.api import Engine, PartitionSpec, Plan, RunSpec, ServeSpec
    from repro.api.serving import Request, Scheduler
    from repro.configs import ARCHS, reduced as make_reduced

    cfg = ARCHS[a.arch]
    if a.reduced:
        cfg = make_reduced(cfg)

    partition = PartitionSpec()
    if a.backend == "spmd":
        dsz, ssz, tsz = (int(x) for x in a.mesh.split(","))
        partition = PartitionSpec(data=dsz, stages=ssz, tp=tsz)
    plan = Plan(arch=cfg, partition=partition,
                serve=ServeSpec(prompt_len=a.prompt_len, gen=a.gen,
                                max_batch=a.batch,
                                temperature=a.temperature),
                run=RunSpec(backend=a.backend))
    eng = Engine(plan)

    if a.requests:
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, a.prompt_len,
                                            dtype=np.int32))
                for i in range(a.requests)]
        rep = Scheduler(eng).run(reqs)
        occ = rep.occupancy()       # None when no decode step ran (gen=1)
        print(f"arch={cfg.name} backend={a.backend} requests={a.requests} "
              f"slots={a.batch} tokens={rep.tokens_out} "
              f"decode={rep.ms_per_token():.1f}ms/tok "
              f"throughput={rep.tokens_per_s():.1f} tok/s "
              f"occupancy={'n/a' if occ is None else f'{occ:.2f}'}")
        lat = sorted(r.latency_s for r in rep.requests)
        print(f"latency: p50={lat[len(lat) // 2] * 1e3:.1f}ms "
              f"max={lat[-1] * 1e3:.1f}ms")
        print("generated ids[rid=0]:", rep.requests[0].tokens)
        return

    rep = eng.generate()
    print(f"arch={cfg.name} backend={a.backend} batch={a.batch} "
          f"prefill({a.prompt_len} tok)={rep.prefill_s * 1e3:.1f}ms "
          f"decode {rep.decode_steps} steps={rep.decode_s * 1e3:.1f}ms "
          f"({rep.ms_per_token():.1f} ms/tok)")
    print("generated ids[0]:", np.asarray(rep.tokens)[0].tolist())


if __name__ == "__main__":
    main()
