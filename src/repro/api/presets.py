"""Named experiment presets: one-line access to the canonical scenarios.

    from repro.api import get_preset, Engine
    report = Engine(get_preset("single_node")).fit()

Every preset is a zero-argument builder returning a validated Plan over a
tiny CPU-runnable config; scale knobs are overridden through Plan.replace
(get_preset forwards keyword overrides, double underscores reach nested
specs: get_preset("paper_hetero", run__max_waves=50, sync__D=4)).

    python -m repro.api.presets                 # list presets
    python -m repro.api.presets --run NAME      # run one end to end
"""
from __future__ import annotations

from typing import Callable

from repro.api.engine import Engine
from repro.api.plan import (ClusterSpec, PartitionSpec, Plan, ReplicaSpec,
                            RunSpec, ServeSpec)
from repro.api.sync import BSP, WSP

PRESETS: dict[str, Callable[[], Plan]] = {}


def preset(name: str):
    def deco(fn: Callable[[], Plan]):
        fn.__preset_name__ = name
        PRESETS[name] = fn
        return fn
    return deco


def list_presets() -> dict[str, str]:
    """name -> first docstring line."""
    return {n: (fn.__doc__ or "").strip().splitlines()[0]
            for n, fn in PRESETS.items()}


def get_preset(name: str, **overrides) -> Plan:
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; known: {sorted(PRESETS)}")
    plan = PRESETS[name]()
    return plan.replace(**overrides) if overrides else plan


def _tiny_arch(name: str = "qwen3-0.6b", **over):
    from repro.configs import ARCHS, reduced
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=256,
                num_heads=2, num_kv_heads=2, head_dim=16,
                num_microbatches=2)
    base.update(over)
    return reduced(ARCHS[name], **base)


@preset("single_node")
def single_node() -> Plan:
    """Two virtual workers on one NVLink node, WSP D=1 — the quickstart."""
    return Plan(arch=_tiny_arch(),
                cluster=ClusterSpec(num_vw=2, topology="single"),
                sync=WSP(D=1),
                run=RunSpec(max_waves=15, batch=8, seq=32))


@preset("paper_hetero")
def paper_hetero() -> Plan:
    """The paper's 4-node V/R/G/Q fleet: 4 VWs, WSP D=2, async push."""
    return Plan(arch=_tiny_arch(),
                cluster=ClusterSpec(num_vw=4, topology="paper"),
                sync=WSP(D=2, pull_every=2, async_push=True),
                run=RunSpec(max_waves=12, batch=8, seq=32))


@preset("whimpy_1gbe")
def whimpy_1gbe() -> Plan:
    """A whimpy heterogeneous pair: NVLink + PCIe nodes over 1 GbE,
    compressed pushes overlapping the next wave's compute."""
    from repro.dist.topology import (ClusterTopology, ETH_1G, NVLINK, PCIE,
                                     Pod)
    topo = ClusterTopology([Pod("node0", ("vw0",), NVLINK),
                            Pod("node1", ("vw1",), PCIE)], inter=ETH_1G)
    return Plan(arch=_tiny_arch(),
                cluster=ClusterSpec(num_vw=2, topology=topo,
                                    time_scale=1e-3),
                sync=WSP(D=2, pull_every=4, async_push=True),
                run=RunSpec(max_waves=12, batch=8, seq=32,
                            codec="topk:0.25"))


@preset("bsp_baseline")
def bsp_baseline() -> Plan:
    """The AllReduce-BSP baseline ("Horovod" analogue) on a 2-node ring."""
    return Plan(arch=_tiny_arch(),
                cluster=ClusterSpec(num_vw=2, topology="2node"),
                sync=BSP(),
                run=RunSpec(max_waves=12, batch=8, seq=32))


@preset("spmd_tiny")
def spmd_tiny() -> Plan:
    """The jitted SPMD wave path on a 1x1x1 mesh (runs on a single CPU
    device; grow data/stages/tp on real meshes)."""
    return Plan(arch=_tiny_arch(stages=1, tp=1),
                partition=PartitionSpec(stages=1, tp=1, data=1),
                sync=WSP(D=0),
                run=RunSpec(backend="spmd", max_waves=8, batch=8, seq=32))


@preset("serve_tiny")
def serve_tiny() -> Plan:
    """Batched greedy serving on the CPU reference path (prefill + decode
    through Engine.generate(), or continuous batching via
    repro.api.serving)."""
    return Plan(arch=_tiny_arch(),
                serve=ServeSpec(prompt_len=8, gen=8, max_batch=4))


@preset("serve_spmd")
def serve_spmd() -> Plan:
    """The pipelined serve steps on a (1, 2, 1) mesh — 2 (fake CPU)
    devices: XLA_FLAGS=--xla_force_host_platform_device_count=2."""
    return Plan(arch=_tiny_arch(num_layers=2),
                partition=PartitionSpec(stages=2, tp=1, data=1),
                serve=ServeSpec(prompt_len=8, gen=8, max_batch=4),
                run=RunSpec(backend="spmd"))


@preset("serve_paged")
def serve_paged() -> Plan:
    """Paged-KV continuous batching: 4-token pages from a pool sized
    below the worst case — variable-length prompts and per-request
    budgets allocate only what they need (repro.api.serving Scheduler)."""
    return Plan(arch=_tiny_arch(),
                serve=ServeSpec(prompt_len=8, gen=8, max_batch=4,
                                page_size=4, max_pages=12))


@preset("serve_kernels")
def serve_kernels() -> Plan:
    """Paged continuous batching on the Pallas kernel backend (interpret
    mode): decode walks the KV pool through the block table *inside* the
    flash-decode kernel (scalar-prefetch index map — no gathered KV view),
    prefill runs the flash-attention kernel, and the SSM families run the
    chunked Pallas mixes. Token streams are bit-identical to the "ref"
    jnp oracle (tests/serve_parity_main.py)."""
    return Plan(arch=_tiny_arch(),
                serve=ServeSpec(prompt_len=8, gen=8, max_batch=4,
                                page_size=4, max_pages=12,
                                kernel_backend="interpret"))


@preset("serve_shared")
def serve_shared() -> Plan:
    """Prefix-shared paged serving under memory pressure: identical
    prompts map onto refcounted shared pages (repro.serve.memory), cold
    indexed pages are reclaimed LRU-first, and in-flight requests are
    preempted + replayed instead of refusing admission — the pool is
    sized below what unshared admission would need."""
    return Plan(arch=_tiny_arch(),
                serve=ServeSpec(prompt_len=8, gen=8, max_batch=4,
                                page_size=4, max_pages=10,
                                share_prefix=True, evict=True,
                                preempt=True))


@preset("serve_cluster")
def serve_cluster() -> Plan:
    """Scale-out serving, HetPipe-style: one big + two whimpy replicas
    behind the topology-priced Router (repro.serve.router). Requests
    sharing a page-aligned prefix stick to one replica's prefix index;
    everything else spreads by load priced with the 'hetero' topology's
    alpha-beta link costs."""
    return Plan(arch=_tiny_arch(),
                partition=PartitionSpec(data=3),
                cluster=ClusterSpec(topology="hetero"),
                serve=ServeSpec(prompt_len=8, gen=8, max_batch=4,
                                page_size=4, share_prefix=True,
                                replicas=(ReplicaSpec(max_batch=4),
                                          ReplicaSpec(max_batch=2),
                                          ReplicaSpec(max_batch=2))))


def main(argv=None):
    import argparse

    import numpy as np

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--run", default=None, metavar="NAME",
                    help="build the named preset and Engine.fit() it")
    ap.add_argument("--waves", type=int, default=0,
                    help="override the preset's max_waves")
    a = ap.parse_args(argv)
    if a.run is None:
        width = max(len(n) for n in PRESETS)
        for n, doc in list_presets().items():
            print(f"  {n:<{width}}  {doc}")
        return 0
    plan = get_preset(a.run, **({"run__max_waves": a.waves} if a.waves
                                else {}))
    print(plan.describe())
    if plan.serve is not None and plan.partition.data > 1:
        # cluster presets demo the Router: shared-prefix traffic sticks
        # to one replica's prefix index, the rest spreads by load
        from repro.api.serving import Request
        from repro.serve.router import Router
        sv = plan.serve
        rng = np.random.default_rng(0)
        common = rng.integers(0, plan.arch.vocab_size, sv.prompt_len,
                              dtype=np.int32)
        reqs = [Request(rid=i, prompt=common.copy(),
                        max_new_tokens=max(1, sv.gen // 2))
                for i in range(4)]
        reqs += [Request(rid=4 + i,
                         prompt=rng.integers(
                             0, plan.arch.vocab_size,
                             int(rng.integers(2, sv.prompt_len + 1)),
                             dtype=np.int32),
                         max_new_tokens=int(rng.integers(1, sv.gen + 1)))
                 for i in range(8)]
        rep = Router(plan).run(reqs)
        assert rep.tokens_out == sum(r.max_new_tokens for r in reqs)
        assert rep.failed_requests == 0, rep.failed_requests
        assert rep.router["affinity_hits"] > 0, rep.router
        assert rep.prefix_hit_tokens > 0, rep.prefix_hit_tokens
        print(f"replicas={rep.router['replicas']} requests={len(reqs)} "
              f"tokens={rep.tokens_out} "
              f"dispatches={rep.router['dispatches']} "
              f"affinity_hits={rep.router['affinity_hits']} "
              f"prefix_hit={rep.prefix_hit_tokens} tok "
              f"throughput={rep.tokens_per_s():.1f} tok/s")
        print("OK")
        return 0
    if plan.serve is not None:
        sv = plan.serve
        if sv.page_size:
            # paged presets demo the continuous-batching Scheduler with
            # mixed prompt lengths and budgets (the paged pool's point);
            # the shared preset instead repeats one full prompt so the
            # prefix index has something to hit
            from repro.api.serving import Request, Scheduler
            rng = np.random.default_rng(0)
            if sv.share_prefix:
                common = rng.integers(0, plan.arch.vocab_size,
                                      sv.prompt_len, dtype=np.int32)
                reqs = [Request(rid=i, prompt=common.copy(),
                                max_new_tokens=max(1, sv.gen // 2))
                        for i in range(2 * sv.max_batch)]
            else:
                reqs = [Request(rid=i,
                                prompt=rng.integers(
                                    0, plan.arch.vocab_size,
                                    int(rng.integers(2, sv.prompt_len + 1)),
                                    dtype=np.int32),
                                max_new_tokens=int(
                                    rng.integers(1, sv.gen + 1)))
                        for i in range(2 * sv.max_batch)]
            rep = Scheduler(Engine(plan)).run(reqs)
            assert rep.tokens_out == sum(r.max_new_tokens for r in reqs)
            pu = rep.page_utilization()
            print(f"requests={len(reqs)} tokens={rep.tokens_out} "
                  f"pages={rep.peak_pages}/{rep.pages_total}"
                  f"(x{rep.page_size} tok) "
                  f"util={0.0 if pu is None else pu:.2f} "
                  f"throughput={rep.tokens_per_s():.1f} tok/s")
            if sv.share_prefix:
                assert rep.prefix_hit_tokens > 0
                assert rep.admit_blocked == 0, rep.admit_blocked
                print(f"memory: prefix_hit={rep.prefix_hit_tokens} tok "
                      f"shared={rep.pages_shared} cow={rep.cow_copies} "
                      f"evictions={rep.evictions} "
                      f"preemptions={rep.preemptions}")
            print("OK")
            return 0
        rep = Engine(plan).generate()
        assert rep.tokens.shape == (sv.max_batch, sv.gen), rep.tokens.shape
        print(f"batch={sv.max_batch} prefill({sv.prompt_len} tok)="
              f"{rep.prefill_s*1e3:.1f}ms decode={rep.ms_per_token():.1f}"
              f"ms/tok throughput={rep.tokens_per_s():.1f} tok/s")
        print("generated ids[0]:", rep.tokens[0].tolist())
        print("OK")
        return 0
    report = Engine(plan).fit()
    t, loss = report.loss_curve()
    print(f"waves={report.waves} wall={report.wall_s:.1f}s "
          f"loss {loss[0]:.3f} -> {np.mean(loss[-4:]):.3f}")
    assert np.mean(loss[-4:]) < loss[0], "did not learn"
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
