"""repro.api — the declarative experiment layer.

One surface for every training scenario the reproduction supports:

    from repro.api import Plan, ClusterSpec, RunSpec, WSP, Engine

    plan = Plan(arch=my_arch,
                cluster=ClusterSpec(num_vw=4, topology="hetero"),
                sync=WSP(D=2, async_push=True),
                run=RunSpec(max_waves=50))
    report = Engine(plan).fit()

Plans are frozen and validated at construction; the Engine dispatches to
the threaded-WSP fleet, the BSP all-reduce loop or the jitted SPMD wave
path from the Plan alone. `repro.api.presets` names the canonical
scenarios. The legacy `repro.runtime.trainer.WSPTrainer` and
`bsp_allreduce_baseline` constructors are deprecation shims over this
layer.

Serving rides the same surface: a Plan with `serve=ServeSpec(...)` runs
batched prefill + autoregressive decode through
`Engine.prefill()/decode()/generate()` (pipelined mesh steps on
backend='spmd', the forward_ref cache path on 'threads'), and
`repro.api.serving` adds a continuous-batching request scheduler returning
a `ServeReport`.

Fault scenarios ride the Plan too: `Plan(faults=FaultPlan(...),
fault_policy=FaultPolicy(...))` injects deterministic, seeded failures
(link outages/loss, worker crashes and slowdowns, PS stalls, serve slot
faults) into the threaded runtime and the Scheduler, with retry/backoff,
heartbeat-driven eviction + elastic rejoin, and graceful serve-side
degradation as the recovery surface (see repro.faults).
"""
from repro.api.engine import Engine
from repro.api.plan import (ClusterSpec, PartitionSpec, Plan, ReplicaSpec,
                            RunSpec, ServeSpec)
from repro.api.presets import PRESETS, get_preset, list_presets
from repro.api.report import (RequestStats, ServeReport, Telemetry,
                              TrainReport)
from repro.api.sync import ASP, BSP, SyncPolicy, UNBOUNDED_D, WSP
from repro.faults import (DegradedRunError, FaultPlan, FaultPolicy,
                          GateTimeout, LinkFault, PSStall, PushTimeout,
                          ReplicaDown, SlotFault, TransportError,
                          WorkerCrash, WorkerSlowdown)

__all__ = [
    "ASP", "BSP", "ClusterSpec", "DegradedRunError", "Engine", "FaultPlan",
    "FaultPolicy", "GateTimeout", "LinkFault", "PSStall", "PartitionSpec",
    "Plan", "PRESETS", "PushTimeout", "ReplicaDown", "ReplicaSpec",
    "RequestStats", "RunSpec", "ServeReport", "ServeSpec", "SlotFault",
    "SyncPolicy", "Telemetry", "TrainReport", "TransportError",
    "UNBOUNDED_D", "WSP", "WorkerCrash", "WorkerSlowdown", "get_preset",
    "list_presets",
]
