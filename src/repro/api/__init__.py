"""repro.api — the declarative experiment layer.

One surface for every training scenario the reproduction supports:

    from repro.api import Plan, ClusterSpec, RunSpec, WSP, Engine

    plan = Plan(arch=my_arch,
                cluster=ClusterSpec(num_vw=4, topology="hetero"),
                sync=WSP(D=2, async_push=True),
                run=RunSpec(max_waves=50))
    report = Engine(plan).fit()

Plans are frozen and validated at construction; the Engine dispatches to
the threaded-WSP fleet, the BSP all-reduce loop or the jitted SPMD wave
path from the Plan alone. `repro.api.presets` names the canonical
scenarios. The legacy `repro.runtime.trainer.WSPTrainer` and
`bsp_allreduce_baseline` constructors are deprecation shims over this
layer.

Serving rides the same surface: a Plan with `serve=ServeSpec(...)` runs
batched prefill + autoregressive decode through
`Engine.prefill()/decode()/generate()` (pipelined mesh steps on
backend='spmd', the forward_ref cache path on 'threads'), and
`repro.api.serving` adds a continuous-batching request scheduler returning
a `ServeReport`.
"""
from repro.api.engine import Engine
from repro.api.plan import (ClusterSpec, PartitionSpec, Plan, RunSpec,
                            ServeSpec)
from repro.api.presets import PRESETS, get_preset, list_presets
from repro.api.report import (RequestStats, ServeReport, Telemetry,
                              TrainReport)
from repro.api.sync import ASP, BSP, SyncPolicy, UNBOUNDED_D, WSP

__all__ = [
    "ASP", "BSP", "ClusterSpec", "Engine", "PartitionSpec", "Plan",
    "PRESETS", "RequestStats", "RunSpec", "ServeReport", "ServeSpec",
    "SyncPolicy", "Telemetry", "TrainReport", "UNBOUNDED_D", "WSP",
    "get_preset", "list_presets",
]
