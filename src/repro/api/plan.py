"""Declarative experiment Plans.

A Plan is the single description of a training OR serving scenario:

    Plan = ArchConfig x ShapeConfig x ClusterSpec x PartitionSpec
           x SyncPolicy x RunSpec [x ServeSpec]

It is frozen and validated at construction, so a malformed scenario fails
where it is written, not three layers down inside a worker thread. The
Engine (repro.api.engine) is the only consumer: it dispatches to the
threaded-WSP, BSP-allreduce or jitted-SPMD backend from the Plan alone.
Setting `serve=ServeSpec(...)` turns the Plan into a serving scenario
(batched prefill + autoregressive decode) executed through
`Engine.prefill()/decode()/generate()` instead of `fit()`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

from repro.configs.base import ArchConfig, ShapeConfig
from repro.api.sync import BSP, SyncPolicy, WSP
from repro.faults.plan import (FaultPlan, FaultPolicy, SERVE_EVENTS,
                               TRAIN_EVENTS, LinkFault, PSStall, ReplicaDown,
                               SlotFault, WorkerCrash, WorkerSlowdown)


@dataclass(frozen=True)
class ClusterSpec:
    """The fleet: how many virtual workers, on what (modeled) network, with
    what simulated heterogeneity."""

    num_vw: int = 1
    # a repro.dist.topology.ClusterTopology, a spec string for
    # make_topology ('single', '2node:ib', 'hetero', 'paper', ...) or None
    # for the zero-latency default
    topology: Any = None
    speeds: Optional[tuple] = None          # per-VW extra seconds/wave
    straggle_fns: Optional[tuple] = None    # per-VW wave -> extra seconds
    fail_at: tuple = ()                     # ((vw_index, wave), ...) failures
    time_scale: float = 1.0                 # scale modeled delays into sleeps

    def __post_init__(self):
        if self.speeds is not None:
            object.__setattr__(self, "speeds", tuple(self.speeds))
        if self.straggle_fns is not None:
            object.__setattr__(self, "straggle_fns",
                               tuple(self.straggle_fns))
        if isinstance(self.fail_at, dict):
            object.__setattr__(self, "fail_at",
                               tuple(sorted(self.fail_at.items())))
        else:
            object.__setattr__(self, "fail_at", tuple(self.fail_at))

    def fail_map(self) -> dict:
        return dict(self.fail_at)


@dataclass(frozen=True)
class PartitionSpec:
    """Mesh/pipeline factorization. Zeros defer to the ArchConfig."""

    stages: int = 0             # 0 -> arch.stages
    tp: int = 0                 # 0 -> arch.tp
    data: int = 1               # SPMD data-parallel mesh size
    num_microbatches: int = 0   # 0 -> arch.num_microbatches
    devices: int = 0            # expected device count (0 -> data*stages*tp)


@dataclass(frozen=True)
class RunSpec:
    """Everything about one run that is neither model, fleet nor sync."""

    backend: str = "threads"    # threads (host-level VWs) | spmd (jitted)
    max_waves: int = 20
    batch: int = 8              # per-VW wave batch
    seq: int = 64
    vocab: int = 0              # 0 -> arch.vocab_size
    optimizer: str = "sgd"
    lr: float = 0.3
    weight_decay: float = 0.1   # only consulted by adamw
    seed: int = 0               # parameter init seed
    data_seed: int = 0
    codec: Optional[str] = None             # 'topk:<r>' | 'int8' | None
    compression_ratio: Optional[float] = None
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    resume: bool = False
    overlap: bool = False       # spmd: software-pipelined (skewed) schedule
    compute_dtype: str = "float32"
    loss_chunk: int = 512


@dataclass(frozen=True)
class ReplicaSpec:
    """One replica's sizing in a data-parallel serve fleet
    (`partition.data` > 1, routed by `repro.serve.router.Router`).

    Zeros defer to the cluster-level ServeSpec, whose `max_batch` /
    `max_pages` are the per-replica *ceiling*: a whimpy replica shrinks
    them (fewer decode slots, a smaller KV page pool) and the Router
    steers short-prompt / short-deadline traffic its way. `host` names
    the replica's endpoint in `cluster.topology` (default "vw{i}") so
    dispatch can price the client->replica link."""

    max_batch: int = 0          # decode slots; 0 -> ServeSpec.max_batch
    max_pages: int = 0          # KV page pool; 0 -> ServeSpec.max_pages
    host: str = ""              # topology endpoint; "" -> "vw{index}"


@dataclass(frozen=True)
class ServeSpec:
    """Frozen serving shapes and sampling for a serve-mode Plan.

    Serving runs batched prefill over `max_batch` prompts of up to
    `prompt_len` tokens, then up to `gen` autoregressive decode positions
    against a cache of `max_len = prompt_len + gen` logical slots.
    temperature 0 is greedy argmax; temperature > 0 samples categorically
    (seeded by sample_seed).

    The Scheduler's full-attention KV lives in a paged pool
    (repro.serve.cache): `page_size` tokens per page (0 -> max_len, the
    contiguous degenerate: one page per slot) drawn from a pool of
    `max_pages` physical pages (0 -> the worst case max_batch *
    ceil(max_len / page_size)); each request allocates only the pages its
    own prompt + budget needs, and admission is refused while the pool is
    exhausted.

    The `repro.serve.memory` policy layer rides three knobs:
    `share_prefix` maps a request's longest indexed prompt prefix onto
    existing refcounted pages (copy-on-write on divergence) instead of
    refilling them; `evict` lets admission reclaim cold indexed pages
    LRU-first under pool pressure (readmitted prefixes recompute their
    prefill); `preempt` kicks an in-flight request — fewest generated
    tokens, or most slack under the scheduler's "deadline" policy — and
    replays it instead of refusing admission. All three are bit-identity
    preserving (token streams never change, only page accounting) and
    inert for families without a full-attention KV pool."""

    prompt_len: int = 24
    gen: int = 16
    max_batch: int = 4
    temperature: float = 0.0
    sample_seed: int = 0
    cache_dtype: str = ""           # "" -> run.compute_dtype; "f8" -> fp8 KV
    page_size: int = 0              # KV page tokens; 0 -> max_len (1 pg/slot)
    max_pages: int = 0              # pool size; 0 -> worst-case B * pages/slot
    share_prefix: bool = False      # refcounted prefix sharing + CoW
    evict: bool = False             # LRU-evict cold indexed pages
    preempt: bool = False           # preempt + replay instead of refusing
    kernel_backend: str = "ref"     # "ref" jnp paths | "interpret"/"tpu"
                                    # Pallas kernels on the serve hot paths
    replicas: tuple = ()            # per-replica ReplicaSpec overrides for a
                                    # data-parallel serve fleet; () with
                                    # partition.data=N -> N homogeneous
                                    # replicas at the cluster-level sizing

    def __post_init__(self):
        object.__setattr__(self, "replicas", tuple(self.replicas))

    @property
    def max_len(self) -> int:
        return self.prompt_len + self.gen


@dataclass(frozen=True)
class Plan:
    arch: Optional[ArchConfig] = None
    shape: Optional[ShapeConfig] = None
    cluster: ClusterSpec = field(default_factory=ClusterSpec)
    partition: PartitionSpec = field(default_factory=PartitionSpec)
    sync: SyncPolicy = field(default_factory=WSP)
    run: RunSpec = field(default_factory=RunSpec)
    serve: Optional[ServeSpec] = None
    faults: Optional[FaultPlan] = None
    fault_policy: FaultPolicy = field(default_factory=FaultPolicy)

    def __post_init__(self):
        self.validate()

    # ---- resolved views -------------------------------------------------
    @property
    def stages(self) -> int:
        return self.partition.stages or (self.arch.stages if self.arch else 1)

    @property
    def tp(self) -> int:
        return self.partition.tp or (self.arch.tp if self.arch else 1)

    @property
    def num_microbatches(self) -> int:
        return self.partition.num_microbatches or \
            (self.arch.num_microbatches if self.arch else 1)

    @property
    def vocab(self) -> int:
        return self.run.vocab or (self.arch.vocab_size if self.arch else 256)

    @property
    def devices_needed(self) -> int:
        return self.partition.devices or \
            (self.partition.data * self.stages * self.tp)

    # ---- validation -----------------------------------------------------
    def validate(self) -> None:
        from repro.dist.compression import make_codec
        from repro.dist.topology import make_topology

        if not isinstance(self.sync, SyncPolicy):
            raise TypeError(f"sync must be a SyncPolicy, got {self.sync!r}")
        self.sync.validate()

        cl, run = self.cluster, self.run
        if cl.num_vw < 1:
            raise ValueError(f"num_vw must be >= 1, got {cl.num_vw}")
        if cl.speeds is not None and len(cl.speeds) != cl.num_vw:
            raise ValueError(f"speeds has {len(cl.speeds)} entries for "
                             f"{cl.num_vw} virtual workers")
        if cl.straggle_fns is not None and \
                len(cl.straggle_fns) != cl.num_vw:
            raise ValueError(f"straggle_fns has {len(cl.straggle_fns)} "
                             f"entries for {cl.num_vw} virtual workers")
        if cl.time_scale < 0:
            raise ValueError(f"time_scale must be >= 0, got {cl.time_scale}")
        bad = [i for i, _ in cl.fail_at if not 0 <= i < cl.num_vw]
        if bad:
            raise ValueError(f"fail_at names worker indices {bad} outside "
                             f"the fleet (num_vw={cl.num_vw}); that failure "
                             f"would silently never be injected")
        if isinstance(cl.topology, str):
            make_topology(cl.topology, cl.num_vw)   # parse errors surface now

        if run.backend not in ("threads", "spmd"):
            raise ValueError(f"unknown backend {run.backend!r}; expected "
                             f"'threads' or 'spmd'")
        if run.max_waves < 0 or run.batch < 1 or run.seq < 1:
            raise ValueError(f"bad run spec: max_waves={run.max_waves} "
                             f"batch={run.batch} seq={run.seq}")
        if run.codec is not None and run.compression_ratio is not None:
            raise ValueError("codec and compression_ratio are two spellings "
                             "of the same knob; set at most one")
        make_codec(run.codec)                       # parse errors surface now
        if run.compression_ratio is not None and \
                not 0.0 < run.compression_ratio <= 1.0:
            raise ValueError(f"compression_ratio must be in (0, 1], got "
                             f"{run.compression_ratio}")
        if run.ckpt_every < 0:
            raise ValueError(f"ckpt_every must be >= 0, got {run.ckpt_every}")

        if isinstance(self.sync, BSP):
            # reject knobs the BSP loop would otherwise silently drop
            if run.codec is not None or run.compression_ratio is not None:
                raise ValueError(
                    "gradient codecs ride the parameter-server push path; "
                    "the BSP loop all-reduces raw deltas — drop "
                    "codec/compression_ratio or use a WSP policy")
            if cl.straggle_fns is not None or cl.fail_at:
                raise ValueError(
                    "straggle_fns/fail_at simulate per-worker behavior in "
                    "the threaded PS runtime; the BSP loop models "
                    "heterogeneity through cluster.speeds only")

        p = self.partition
        for name in ("stages", "tp", "data", "num_microbatches", "devices"):
            if getattr(p, name) < 0:
                raise ValueError(f"partition.{name} must be >= 0")
        if self.arch is not None or p.num_microbatches:
            nm = self.num_microbatches
            if nm >= 1 and run.batch % nm:
                raise ValueError(
                    f"per-VW batch {run.batch} is not divisible by "
                    f"num_microbatches {nm} (the wave packs the batch into "
                    f"Nm pipeline minibatches)")

        if run.backend == "threads" and \
                (p.stages or p.tp or (p.data != 1 and self.serve is None)):
            raise ValueError(
                "PartitionSpec.stages/tp/data factor the spmd mesh; the "
                "threads backend runs each VW's wave step whole (only "
                "partition.num_microbatches applies; on a serve Plan "
                "partition.data counts Router replicas) — unset them or "
                "use backend='spmd'")
        if run.backend == "spmd":
            if self.arch is None:
                raise ValueError("the spmd backend builds the pipelined wave "
                                 "step from the architecture; Plan.arch is "
                                 "required")
            model = self.stages * self.tp
            if self.devices_needed % model:
                raise ValueError(
                    f"stages*tp = {self.stages}*{self.tp} = {model} does not "
                    f"divide the device count {self.devices_needed}")
            if p.data * model != self.devices_needed:
                raise ValueError(
                    f"mesh data*stages*tp = {p.data}*{self.stages}*{self.tp} "
                    f"= {p.data * model} != devices {self.devices_needed}")
            if isinstance(self.sync, WSP):
                if self.sync.D != 0:
                    raise ValueError(
                        "the jitted SPMD backend reduces every wave "
                        "collectively (D = 0); true-async D > 0 needs "
                        "backend='threads'")
                if self.sync.async_push:
                    raise ValueError("async_push is a threads-backend knob; "
                                     "spmd overlap is run.overlap (the "
                                     "skewed pipeline schedule)")
            elif not isinstance(self.sync, BSP):
                raise ValueError(f"spmd backend supports WSP(D=0) or BSP, "
                                 f"got {self.sync.describe()}")
            if self.shape is not None:
                if self.shape.kind != "train":
                    raise ValueError(f"Engine.fit trains; shape kind "
                                     f"{self.shape.kind!r} is a serving "
                                     f"shape")
                if self.shape.seq_len != run.seq or \
                        self.shape.global_batch != p.data * run.batch:
                    raise ValueError(
                        f"shape ({self.shape.global_batch}x"
                        f"{self.shape.seq_len}) disagrees with "
                        f"run.batch*data x run.seq ({p.data * run.batch}x"
                        f"{run.seq}); the loader and the jitted step must "
                        f"see the same shapes")
            if run.codec is not None or run.compression_ratio is not None \
                    or cl.topology is not None:
                raise ValueError(
                    "codec/compression_ratio/topology model the host-level "
                    "PS path; the jitted spmd backend reduces in-graph — "
                    "unset them or use backend='threads'")
            if cl.num_vw != 1 or cl.speeds is not None \
                    or cl.straggle_fns is not None or cl.fail_at:
                raise ValueError(
                    "the spmd backend's DP width is partition.data and the "
                    "mesh is homogeneous; ClusterSpec heterogeneity knobs "
                    "(num_vw/speeds/straggle_fns/fail_at) only drive the "
                    "threaded fleet — unset them or use backend='threads'")

        self._validate_faults()
        if self.serve is not None:
            self._validate_serve()

    def _validate_faults(self) -> None:
        """Fault scenarios are validated against the Plan they ride: event
        indices must land inside the fleet/run/batch, train events need a
        train Plan (and vice versa), and the threaded PS runtime is the
        only backend with fault seams."""
        if not isinstance(self.fault_policy, FaultPolicy):
            raise TypeError(f"fault_policy must be a FaultPolicy, got "
                            f"{self.fault_policy!r}")
        if self.faults is None:
            return
        if not isinstance(self.faults, FaultPlan):
            raise TypeError(f"faults must be a FaultPlan, got "
                            f"{self.faults!r}")
        cl, run, pol = self.cluster, self.run, self.fault_policy
        serving = self.serve is not None
        if serving:
            bad = self.faults.of_type(*TRAIN_EVENTS)
            if bad:
                raise ValueError(
                    f"this Plan serves; training fault events "
                    f"{sorted({type(e).__name__ for e in bad})} would "
                    f"silently never fire — use SlotFault (or drop faults)")
            for ev in self.faults.of_type(SlotFault):
                if ev.slot >= self.serve.max_batch:
                    raise ValueError(
                        f"SlotFault names slot {ev.slot} outside the decode "
                        f"batch (max_batch={self.serve.max_batch})")
            replicas = max(1, self.partition.data)
            for ev in self.faults.of_type(ReplicaDown):
                if replicas == 1:
                    raise ValueError(
                        "ReplicaDown kills one replica of a data-parallel "
                        "serve fleet; this Plan has a single replica "
                        "(partition.data=1) — the Router would have no "
                        "survivor to re-dispatch onto")
                if ev.replica >= replicas:
                    raise ValueError(
                        f"ReplicaDown names replica {ev.replica} outside "
                        f"the fleet (partition.data={replicas}); that fault "
                        f"would silently never be injected")
            return
        bad = self.faults.of_type(*SERVE_EVENTS)
        if bad:
            raise ValueError(
                f"{type(bad[0]).__name__} is a serving fault; this Plan "
                f"trains — use the training events (LinkFault/WorkerCrash/"
                f"WorkerSlowdown/PSStall) or set Plan.serve")
        if run.backend != "threads" or isinstance(self.sync, BSP):
            raise ValueError(
                "fault injection seams live in the threaded parameter-"
                "server runtime (transport, PS, worker fleet); the "
                f"{'spmd' if run.backend != 'threads' else 'BSP'} backend "
                f"has none of them — drop Plan.faults or use "
                f"backend='threads' with a WSP policy")
        for ev in self.faults.of_type(WorkerCrash, WorkerSlowdown):
            if ev.vw >= cl.num_vw:
                raise ValueError(
                    f"{type(ev).__name__} names worker {ev.vw} outside the "
                    f"fleet (num_vw={cl.num_vw}); that fault would silently "
                    f"never be injected")
            if ev.wave >= run.max_waves:
                raise ValueError(
                    f"{type(ev).__name__}(vw={ev.vw}) anchors at wave "
                    f"{ev.wave} but the run stops after "
                    f"{run.max_waves} waves")
        crashes = self.faults.of_type(WorkerCrash)
        if crashes and cl.num_vw > 1 and pol.evict_lag <= 0:
            raise ValueError(
                "a WorkerCrash dies without deregistering: survivors stall "
                "at the staleness gate until the crashed worker is evicted. "
                "Set FaultPolicy.evict_lag (<= D) so the supervisor detects "
                "and evicts it, or drop the crash event")
        if crashes and pol.evict_lag > 0 and isinstance(self.sync, WSP) \
                and pol.evict_lag > max(1, self.sync.D):
            raise ValueError(
                f"FaultPolicy.evict_lag={pol.evict_lag} exceeds the "
                f"staleness bound D={self.sync.D}: survivors deadlock at "
                f"the gate before the lag detector can fire — set "
                f"evict_lag <= max(1, D)")

    def _validate_serve(self) -> None:
        """Serve-mode Plans: reject train-only knobs the serve path would
        silently drop (the same convention the train backends follow)."""
        sv, run, cl = self.serve, self.run, self.cluster
        if not isinstance(sv, ServeSpec):
            raise TypeError(f"serve must be a ServeSpec, got {sv!r}")
        if self.arch is None:
            raise ValueError("serving builds the model from the "
                             "architecture; Plan.arch is required when "
                             "Plan.serve is set")
        if sv.prompt_len < 1 or sv.gen < 1 or sv.max_batch < 1:
            raise ValueError(f"bad serve spec: prompt_len={sv.prompt_len} "
                             f"gen={sv.gen} max_batch={sv.max_batch} "
                             f"(all must be >= 1)")
        if sv.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{sv.temperature}")
        if sv.cache_dtype not in ("", "f8"):
            raise ValueError(f"unknown serve cache_dtype "
                             f"{sv.cache_dtype!r}; expected '' (compute "
                             f"dtype) or 'f8'")
        if sv.kernel_backend not in ("ref", "interpret", "tpu"):
            raise ValueError(f"unknown serve kernel_backend "
                             f"{sv.kernel_backend!r}: expected one of "
                             f"('ref', 'interpret', 'tpu')")
        if sv.page_size < 0 or sv.max_pages < 0:
            raise ValueError(f"page_size={sv.page_size} and "
                             f"max_pages={sv.max_pages} must be >= 0 "
                             f"(0 defers to the contiguous worst case)")
        from repro.serve.cache import make_layout
        make_layout(sv.max_batch, sv.max_len, page_size=sv.page_size,
                    max_pages=sv.max_pages)     # geometry errors surface now
        if sv.evict and not sv.share_prefix:
            raise ValueError(
                "evict=True without share_prefix=True is a silent no-op: "
                "only the prefix index retains pages past their last "
                "mapping, so there is never a cold page to evict — enable "
                "share_prefix or drop evict")
        if self.shape is not None:
            raise ValueError("serve shapes (prefill/decode/max batch) are "
                             "frozen in Plan.serve; drop Plan.shape")
        if not isinstance(self.sync, WSP) or self.sync.D != 0 \
                or self.sync.async_push:
            raise ValueError(
                f"serving runs no gradient synchronization; Plan.sync must "
                f"be the default WSP(D=0) on a serve Plan, got "
                f"{self.sync.describe()}")
        if run.ckpt_dir or run.ckpt_every or run.resume:
            raise ValueError(
                "ckpt_dir/ckpt_every/resume drive the training loop; a "
                "serve Plan has no optimizer state to checkpoint — use "
                "Engine.restore() to load trained weights before serving")
        if run.codec is not None or run.compression_ratio is not None:
            raise ValueError(
                "gradient codecs ride the training push path; the serve "
                "path moves KV cache, not deltas — drop "
                "codec/compression_ratio (use serve.cache_dtype='f8' to "
                "shrink the cache)")
        if cl.num_vw != 1 or cl.speeds is not None \
                or cl.straggle_fns is not None or cl.fail_at:
            raise ValueError(
                "ClusterSpec heterogeneity knobs (num_vw/speeds/"
                "straggle_fns/fail_at) drive the threaded training fleet; "
                "the serve path batches requests on replicas sized by "
                "partition.data + ServeSpec.replicas, and cluster.topology "
                "alone prices the Router's dispatch — unset the rest")
        p = self.partition
        if run.backend == "spmd":
            if p.data != 1 or sv.replicas:
                raise ValueError(
                    "spmd serve batches live whole on the model (stage x "
                    "tp) mesh; data-parallel serve replicas are threads-"
                    "backend only for now — set partition.data=1 (and drop "
                    "ServeSpec.replicas) or use backend='threads'")
            if cl.topology is not None:
                raise ValueError(
                    "cluster.topology prices the Router's dispatch over "
                    "threads-backend serve replicas; the spmd mesh is a "
                    "single replica — unset it")
            return
        if p.data < 1:
            raise ValueError(
                f"partition.data counts the Router's serve replicas and "
                f"must be >= 1, got {p.data}")
        if isinstance(cl.topology, str):
            from repro.dist.topology import make_topology
            make_topology(cl.topology, max(1, p.data))  # parse errors now
        if sv.replicas:
            if len(sv.replicas) != p.data:
                raise ValueError(
                    f"ServeSpec.replicas carries {len(sv.replicas)} replica "
                    f"specs for partition.data={p.data} replicas; give one "
                    f"spec per replica (or none for a homogeneous fleet)")
            for i, r in enumerate(sv.replicas):
                if not isinstance(r, ReplicaSpec):
                    raise TypeError(f"ServeSpec.replicas[{i}] must be a "
                                    f"ReplicaSpec, got {r!r}")
                mb = r.max_batch or sv.max_batch
                mp = r.max_pages or sv.max_pages
                if not 1 <= mb <= sv.max_batch:
                    raise ValueError(
                        f"replica {i}: max_batch={mb} outside [1, "
                        f"ServeSpec.max_batch={sv.max_batch}] — the "
                        f"cluster-level spec is the per-replica ceiling "
                        f"(whimpy replicas shrink it, never exceed it)")
                if mp and sv.max_pages and mp > sv.max_pages:
                    raise ValueError(
                        f"replica {i}: max_pages={mp} exceeds the cluster-"
                        f"level ceiling ServeSpec.max_pages={sv.max_pages}")
                # a replica pool that cannot hold one worst-case request
                # could never admit anything — surface it here, not mid-run
                make_layout(mb, sv.max_len, page_size=sv.page_size,
                            max_pages=mp)

    # ---- ergonomics -----------------------------------------------------
    def replace(self, **kw) -> "Plan":
        """dataclasses.replace with one level of nesting via double
        underscores: plan.replace(run__max_waves=8, sync__D=2)."""
        nested: dict[str, dict] = {}
        top: dict[str, Any] = {}
        for k, v in kw.items():
            if "__" in k:
                head, rest = k.split("__", 1)
                nested.setdefault(head, {})[rest] = v
            else:
                top[k] = v
        for head, sub in nested.items():
            cur = top.get(head, getattr(self, head))
            top[head] = dataclasses.replace(cur, **sub)
        return dataclasses.replace(self, **top)

    def describe(self) -> str:
        arch = self.arch.name if self.arch else "<injected wave step>"
        if self.serve is not None:
            sv = self.serve
            reps = (f"replicas={self.partition.data}, "
                    if self.partition.data > 1 else "")
            return (f"Plan({arch}, serve, backend={self.run.backend}, "
                    f"{reps}batch={sv.max_batch}, prompt={sv.prompt_len}, "
                    f"gen={sv.gen}, "
                    f"{'greedy' if sv.temperature == 0 else 'sampled'})")
        topo = self.cluster.topology
        topo = topo if isinstance(topo, (str, type(None))) else "custom"
        return (f"Plan({arch}, backend={self.run.backend}, "
                f"vw={self.cluster.num_vw}, topology={topo}, "
                f"{self.sync.describe()}, waves={self.run.max_waves})")
