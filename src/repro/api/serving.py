"""Continuous-batching request scheduler over the Engine's serve surface.

The Engine's generate() runs one aligned batch: every slot prefills and
retires together. Real traffic is ragged — requests arrive with different
prompt lengths and budgets, and finish at different depths. The Scheduler
closes that gap with the standard continuous-batching loop, built on the
paged cache subsystem (repro.serve.cache):

  admit   pop queued requests into free batch slots — each admission
          allocates exactly the KV pages its prompt + generation budget
          needs from the CacheStore pool (no worst-case reservation) and
          is *refused* while the pool is exhausted; one variable-length
          prefill call (right-padded prompts + a per-row length vector)
          scatters K/V straight into the allocated pages and adopts the
          per-slot ring/SSM state into the assigned slots. With
          ServeSpec.share_prefix the repro.serve.memory manager first
          maps the request's longest indexed prompt prefix onto existing
          refcounted pages (copy-on-write on a fully-matched partial
          page) and prefill skips writing them; with evict, cold indexed
          pages are reclaimed LRU-first under pressure; with preempt, an
          in-flight victim (fewest tokens generated, or most deadline
          slack) is requeued and replayed instead of refusing admission
  decode  one jitted decode call advances every active slot by one token;
          slots sit at different depths, carried by the per-row position
          vector (core.wave pos_per_row / forward_ref vector pos), and
          full-attention K/V is read through each slot's block table
  retire  finished sequences free their pages and slots

Admission policy: "fifo" (default) admits strictly in arrival order, so no
request starves. "deadline" orders the admit queue by slack — a request's
`deadline` (in decode steps) minus the current step minus the tokens it
still needs — with FIFO order among slack ties (requests without a
deadline have infinite slack and never preempt each other's arrival
order). Per-request token picks are keyed by (sample_seed, rid, k), so a
request's output is independent of which neighbors it was co-batched with
— bit-identical across schedules for the dense/attention-free families
(MoE capacity routing is batch-coupled by construction).

    from repro.api import Engine, get_preset
    from repro.api.serving import Request, Scheduler
    eng = Engine(get_preset("serve_tiny"))
    reqs = [Request(rid=i, prompt=prompts[i]) for i in range(8)]
    report = Scheduler(eng).run(reqs)        # -> ServeReport
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

from repro.api.engine import Engine
from repro.api.report import RequestStats, ServeReport
from repro.serve.memory import MemoryManager

POLICIES = ("fifo", "deadline")


class StopServing(Exception):
    """Raised by a run() callback to abort serving mid-run — the Router's
    replica-down injection. The Scheduler stops immediately and returns a
    report covering only the requests already retired (aborted_step marks
    where); in-flight and queued requests are simply absent, so the caller
    can re-dispatch them (replay from the prompt is bit-identity-safe:
    token picks are keyed by (sample_seed, rid, k))."""


@dataclass
class Request:
    """One serving request: a prompt of at most serve.prompt_len token
    ids, an optional per-request generation budget (0 -> serve.gen), and
    an optional deadline in decode steps (0 -> none; consulted by the
    Scheduler's "deadline" admission policy)."""

    rid: int
    prompt: Any                 # [<= prompt_len] token ids
    max_new_tokens: int = 0
    deadline: int = 0


class _Slot:
    """An in-flight request occupying one decode-batch row."""

    __slots__ = ("req", "stats", "limit", "next_pos", "last_tok", "t_admit",
                 "prompt")

    def __init__(self, req, stats, limit, next_pos, last_tok, t_admit,
                 prompt=None):
        self.req, self.stats, self.limit = req, stats, limit
        self.next_pos, self.last_tok = next_pos, last_tok
        self.t_admit = t_admit
        self.prompt = prompt


class Scheduler:
    def __init__(self, engine: Engine, *, policy: str = "fifo"):
        plan = engine.plan
        if plan.serve is None:
            raise ValueError("the Scheduler drives serve Plans; Plan.serve "
                             "is unset — give the Plan a ServeSpec")
        if plan.arch.frontend != "none":
            raise ValueError(
                f"{plan.arch.name} is a stub-frontend architecture (inputs "
                f"are precomputed embeddings, not token ids); the request "
                f"scheduler feeds generated ids back — serve it through "
                f"Engine.generate() instead")
        if policy not in POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"expected one of {POLICIES}")
        self.engine = engine
        self.sv = plan.serve
        self.policy = policy
        self.tracer = engine.tracer

    # ------------------------------------------------------------------
    def _pick_one(self, row, rid: int, k: int, key) -> int:
        """Next token for one request, keyed by (rid, k) so co-batching
        never changes a request's sample stream."""
        if self.sv.temperature == 0:
            return int(np.argmax(row))
        rk = jax.random.fold_in(jax.random.fold_in(key, rid), k)
        return int(jax.random.categorical(
            rk, np.asarray(row, np.float32) / self.sv.temperature))

    def _limit(self, r: Request) -> int:
        return r.max_new_tokens or self.sv.gen

    def _admit_order(self, queue, step):
        """Indices into `queue` in admission order. FIFO admits in arrival
        order; the deadline policy sorts by slack (deadline - step -
        tokens still needed) but the sort is stable, so requests with
        equal slack — including every request without a deadline — keep
        strict FIFO order among themselves (no starvation)."""
        if self.policy == "fifo":
            return list(range(len(queue)))
        def slack(r):
            return (r.deadline - step - self._limit(r)) if r.deadline \
                else float("inf")
        return sorted(range(len(queue)), key=lambda i: slack(queue[i]))

    def run(self, requests, *, callback=None, store=None,
            mm=None) -> ServeReport:
        """Serve `requests` to completion. `callback(step, active_slots)`
        fires after every batched decode step (raise StopServing from it
        to abort). `store`/`mm` inject a persistent CacheStore +
        MemoryManager (the Router's replicas keep theirs warm across
        dispatch rounds, so a re-dispatched shared prefix still hits the
        index); by default both are created fresh for this run."""
        eng, sv = self.engine, self.sv
        B, P = sv.max_batch, sv.prompt_len
        plan = eng.plan
        key = jax.random.PRNGKey(sv.sample_seed)
        queue = [(np.asarray(r.prompt), r) for r in requests]
        for prompt, r in queue:
            if prompt.ndim != 1 or not 1 <= prompt.shape[0] <= P:
                raise ValueError(
                    f"request {r.rid}: prompt shape {prompt.shape} must be "
                    f"[1..{P}] token ids; the compiled prefill width is "
                    f"frozen in the Plan (ServeSpec.prompt_len) but shorter "
                    f"prompts are right-padded and allocate only their own "
                    f"pages")
            if not 0 <= r.max_new_tokens <= sv.gen:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                    f"must be in [0 (= the ServeSpec default), "
                    f"ServeSpec.gen={sv.gen}] — slots allocate pages for "
                    f"at most prompt + gen positions")
            if r.deadline < 0:
                raise ValueError(f"request {r.rid}: deadline must be >= 0 "
                                 f"(0 = none), got {r.deadline}")
        if (store is None) != (mm is None):
            raise ValueError("store and mm persist together: inject both "
                             "(the mm indexes that store's pages) or "
                             "neither")
        if store is None:
            store = eng.serve_store()
        elif mm.store is not store:
            raise ValueError("the injected MemoryManager indexes a "
                             "different CacheStore than the one passed")
        report = ServeReport(arch=plan.arch.name, backend=plan.run.backend,
                             max_batch=B, page_size=store.layout.page_size,
                             pages_total=store.pages_total)
        active: dict[int, _Slot] = {}
        free = list(range(B))
        step = 0
        tr = self.tracer
        t_start = time.monotonic()
        injector = eng.fault_injector()
        fpol = plan.fault_policy
        quarantined: set[int] = set()
        retries_by_rid: dict[int, int] = {}
        if mm is None:
            mm = MemoryManager(store, share_prefix=sv.share_prefix,
                               evict=sv.evict, preempt=sv.preempt,
                               policy=self.policy, metrics=tr.metrics)
        # a persistent store/mm carries counters from earlier runs; report
        # this run's contribution as deltas from these baselines
        base = (mm.prefix_hit_tokens, mm.pages_shared, mm.evictions,
                mm.readmit_recomputes, store.cow_copies)
        preempted_rids: set[int] = set()

        def retire(s: int, slot: _Slot):
            slot.stats.finished_step = step
            slot.stats.latency_s = time.monotonic() - slot.t_admit
            report.requests.append(slot.stats)
            mm.went_cold(store.free(s), step)
            if s not in quarantined:
                free.append(s)
                free.sort()
            tr.instant("sched", "retire", rid=slot.req.rid, slot=s,
                       step=step, tokens=len(slot.stats.tokens))

        def shed_queue(reason: str):
            for prompt, r in queue:
                stats = RequestStats(rid=r.rid, prompt_len=prompt.shape[0],
                                     shed=True)
                report.requests.append(stats)
                report.shed += 1
                tr.instant("sched", "shed", rid=r.rid, step=step,
                           reason=reason)
                tr.metrics.counter_inc("fault/shed")
            queue.clear()

        def requeue(s: int, slot: _Slot):
            """Recover by replay: the request goes back to the *front* of
            the queue and re-prefills from its prompt. Token picks are
            keyed by (rid, k), so the replayed stream is bit-identical to
            the one a fault-free scheduler would have produced."""
            del active[s]
            mm.went_cold(store.free(s), step)
            if s not in quarantined:
                free.append(s)
                free.sort()
            queue.insert(0, (slot.prompt, slot.req))
            report.requeues += 1
            tr.instant("sched", "requeue", rid=slot.req.rid, slot=s,
                       step=step, retries=slot.stats.retries)
            tr.metrics.counter_inc("fault/requeues")

        def preempt_slot(s: int):
            """Preempt an in-flight request under pool pressure: release
            its pages and replay it from the prompt. Token picks are
            keyed by (rid, k), so the replayed stream is bit-identical
            to the uninterrupted one — preemption trades latency for
            admission, never correctness."""
            slot = active.pop(s)
            mm.went_cold(store.free(s), step)
            if s not in quarantined:
                free.append(s)
                free.sort()
            preempted_rids.add(slot.req.rid)
            report.preemptions += 1
            tr.instant("sched", "preempt", rid=slot.req.rid, slot=s,
                       step=step, tokens=len(slot.stats.tokens))
            tr.metrics.counter_inc("serve/preemptions")
            return (slot.prompt, slot.req)

        def fail_request(s: int, slot: _Slot):
            slot.stats.failed = True
            report.failed_requests += 1
            tr.instant("sched", "request_failed", rid=slot.req.rid, slot=s,
                       step=step, retries=slot.stats.retries)
            del active[s]
            retire(s, slot)

        def try_reprefill(s: int, slot: _Slot) -> bool:
            """Recover in place: rebuild the slot's cache by prefilling
            prompt + already-generated tokens (the request keeps its
            tokens; only the transient per-slot state is rebuilt). Only
            possible while that sequence still fits the compiled prefill
            width — False falls back to requeue."""
            seq = np.concatenate([
                np.asarray(slot.prompt, np.int32),
                np.asarray(slot.stats.tokens[:-1], np.int32)])
            if seq.shape[0] > P:
                return False
            del active[s]
            mm.went_cold(store.free(s), step)
            if s in quarantined:
                if not free:
                    return False        # no healthy slot left to rebuild on
                s2 = free.pop(0)
            else:
                s2 = s
            need = slot.stats.prompt_len + slot.limit
            if not mm.make_room(store.layout.pages_for(need)
                                if store._has_pool else 0):
                # the slot's own prompt pages went cold under an index
                # hold and eviction can't reclaim enough — replay instead
                if s2 != s or s not in quarantined:
                    free.append(s2)
                    free.sort()
                return False
            store.alloc(s2, need)
            prompts = np.zeros((B, P), np.int32)
            prompts[0, :seq.shape[0]] = seq
            lens = np.ones(B, np.int32)
            lens[0] = seq.shape[0]
            t0 = time.monotonic()
            with tr.span("sched", "reprefill", rid=slot.req.rid, slot=s2,
                         depth=int(seq.shape[0])):
                eng.prefill_into(store, prompts, lens, [s2])
            report.prefill_s += time.monotonic() - t0
            report.prefill_calls += 1
            report.reprefills += 1
            slot.stats.slot = s2
            active[s2] = slot
            tr.metrics.counter_inc("fault/reprefills")
            return True

        def inject_slot_faults():
            """Fire this decode step's injected slot faults: quarantine the
            slot and recover its request under the retry budget."""
            for s in injector.slot_faults(step):
                report.slot_faults += 1
                tr.instant("sched", "slot_fault", slot=s, step=step)
                tr.metrics.counter_inc("fault/slot_faults")
                if fpol.quarantine_slots and s not in quarantined:
                    quarantined.add(s)
                    report.quarantined += 1
                    if s in free:
                        free.remove(s)
                slot = active.get(s)
                if slot is None:
                    continue            # the faulted slot was empty
                slot.stats.retries += 1
                retries_by_rid[slot.req.rid] = slot.stats.retries
                if slot.stats.retries > fpol.slot_retry_budget:
                    fail_request(s, slot)
                elif fpol.slot_recovery == "reprefill" \
                        and try_reprefill(s, slot):
                    pass
                else:
                    if s in active:     # a failed reprefill freed the slot
                        requeue(s, slot)
                    else:
                        queue.insert(0, (slot.prompt, slot.req))
                        report.requeues += 1
                        tr.metrics.counter_inc("fault/requeues")

        faulted_steps: set[int] = set()
        while queue or active:
            # ---- graceful degradation under sustained fault pressure ----
            if queue and fpol.shed_after_faults \
                    and report.slot_faults >= fpol.shed_after_faults:
                shed_queue("fault_pressure")
            if queue and not active and not free:
                # every slot is quarantined: nothing can ever be admitted
                # again — shed the remainder instead of spinning forever
                shed_queue("no_healthy_slots")
            # ---- admit: policy order into the lowest slots, page-gated --
            if free and queue:
                admits = []
                order = self._admit_order([r for _, r in queue], step)
                taken = []
                requeued = []
                for qi in order:
                    if not free:
                        break
                    prompt, r = queue[qi]
                    need = prompt.shape[0] + self._limit(r)
                    hit, pages, need_fresh = mm.plan_admit(prompt, need)
                    if not mm.make_room(need_fresh, protect=pages):
                        # pool exhausted: before refusing, try to preempt
                        # an in-flight victim (never one that was already
                        # preempted — bounds preemptions at one per rid)
                        vict = (mm.victim(active, step, need_fresh)
                                if r.rid not in preempted_rids else None)
                        if vict is not None:
                            requeued.append(preempt_slot(vict))
                        if vict is None \
                                or not mm.make_room(need_fresh,
                                                    protect=pages):
                            # stop admitting rather than over-reserving;
                            # retirements will free pages
                            report.admit_blocked += 1
                            tr.instant("sched", "refuse", rid=r.rid,
                                       step=step, need_tokens=need,
                                       pages_in_use=store.pages_in_use)
                            break
                    s = free.pop(0)
                    skip = mm.admit(s, prompt, need, hit, pages, step)
                    taken.append(qi)
                    admits.append((r, prompt, s, skip))
                for qi in sorted(taken, reverse=True):
                    del queue[qi]
                # preempted victims re-enter at the queue front (inserted
                # only after the del loop — `taken` indexes the old queue)
                for item in reversed(requeued):
                    queue.insert(0, item)
                if admits:
                    group = report.prefill_calls
                    for r, prompt, s, skip in admits:
                        tr.instant("sched", "admit", rid=r.rid, slot=s,
                                   step=step, group=group,
                                   prompt_len=prompt.shape[0],
                                   shared_pages=skip,
                                   pages_in_use=store.pages_in_use)
                    prompts = np.zeros((B, P), np.int32)
                    lens = np.ones(B, np.int32)
                    for j, (r, prompt, _, _) in enumerate(admits):
                        prompts[j, :prompt.shape[0]] = prompt
                        lens[j] = prompt.shape[0]
                    t0 = time.monotonic()
                    with tr.span("sched", "prefill_group", group=group,
                                 rows=len(admits)):
                        logits = np.asarray(eng.prefill_into(
                            store, prompts, lens,
                            [s for _, _, s, _ in admits],
                            skip_pages=[skip for *_, skip in admits]))
                    dt = time.monotonic() - t0
                    report.prefill_s += dt
                    report.prefill_calls += 1
                    # TTFT: arrival (run start — all requests arrive
                    # together) to the end of this admission group's
                    # prefill; the group's cost enters each member once
                    ttft = time.monotonic() - t_start
                    for j, (r, prompt, s, _) in enumerate(admits):
                        tok = self._pick_one(logits[j], r.rid, 0, key)
                        stats = RequestStats(rid=r.rid,
                                             prompt_len=prompt.shape[0],
                                             tokens=[tok],
                                             admitted_step=step,
                                             slot=s, group=group,
                                             prefill_s=dt, ttft_s=ttft,
                                             retries=retries_by_rid.get(
                                                 r.rid, 0))
                        tr.metrics.observe("serve/ttft_s", ttft)
                        slot = _Slot(r, stats, self._limit(r),
                                     next_pos=prompt.shape[0], last_tok=tok,
                                     t_admit=t0, prompt=prompt)
                        if len(stats.tokens) >= slot.limit:
                            retire(s, slot)
                        else:
                            active[s] = slot
            # ---- injected slot faults fire at their decode step ---------
            if injector is not None and step not in faulted_steps:
                # consulted once per step value: a recovery that empties
                # the batch loops back here without re-firing the fault
                faulted_steps.add(step)
                inject_slot_faults()
            if not active:
                continue
            # ---- one batched decode step over every active slot ---------
            toks = np.zeros((B, 1), np.int32)
            pos = np.zeros(B, np.int32)
            for s, slot in active.items():
                toks[s, 0] = slot.last_tok
                pos[s] = slot.next_pos
            t0 = time.monotonic()
            with tr.span("sched", "decode_step", step=step,
                         slots=len(active), pages=store.pages_in_use):
                logits, _ = eng.decode(toks, store, pos)
                logits = np.asarray(logits)
            report.decode_s += time.monotonic() - t0
            report.decode_steps += 1
            report.slot_steps += len(active)
            report.page_steps += store.pages_in_use
            tr.counter("sched", "active_slots", len(active))
            tr.counter("sched", "pages_in_use", store.pages_in_use)
            step += 1
            # ---- advance / retire --------------------------------------
            for s in sorted(active):
                slot = active[s]
                tok = self._pick_one(logits[s], slot.req.rid,
                                     len(slot.stats.tokens), key)
                slot.stats.tokens.append(tok)
                slot.next_pos += 1
                slot.last_tok = tok
                if len(slot.stats.tokens) >= slot.limit:
                    del active[s]
                    retire(s, slot)
            if callback is not None:
                try:
                    callback(step, len(active))
                except StopServing:
                    # abort: the replica died — report only what retired;
                    # the Router replays the rest on the survivors
                    report.aborted_step = step
                    tr.instant("sched", "aborted", step=step,
                               in_flight=len(active), queued=len(queue))
                    break
        report.wall_s = time.monotonic() - t_start
        report.peak_pages = store.peak_pages
        report.prefix_hit_tokens = mm.prefix_hit_tokens - base[0]
        report.pages_shared = mm.pages_shared - base[1]
        report.cow_copies = store.cow_copies - base[4]
        report.evictions = mm.evictions - base[2]
        report.readmit_recomputes = mm.readmit_recomputes - base[3]
        if mm.share_prefix and mm.prompt_tokens:
            tr.metrics.gauge_set("serve/prefix_hit_rate",
                                 mm.prefix_hit_tokens / mm.prompt_tokens)
        report.requests.sort(key=lambda r: r.rid)
        return eng.attach_telemetry(report)


def serve(engine: Engine, requests, *, callback=None) -> ServeReport:
    """One-shot convenience: Scheduler(engine).run(requests)."""
    return Scheduler(engine).run(requests, callback=callback)
