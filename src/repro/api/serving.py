"""Continuous-batching request scheduler over the Engine's serve surface.

The Engine's generate() runs one aligned batch: every slot prefetches and
retires together. Real traffic is ragged — requests arrive while a decode
batch is in flight and finish at different depths. The Scheduler closes
that gap with the standard continuous-batching loop:

  admit   pop queued requests into free batch slots: one padded prefill
          call computes their caches, whose rows are copied into the
          assigned slots (whole-row adoption also clears any stale state
          left by the slot's previous occupant)
  decode  one jitted decode call advances every active slot by one token;
          slots sit at different depths, carried by the per-row position
          vector (core.wave pos_per_row / forward_ref vector pos)
  retire  finished sequences free their slots for the next admission

Requests are admitted strictly FIFO, so no request starves: each admission
takes the longest-waiting request first. Per-request token picks are keyed
by (sample_seed, rid, k), so a request's output is independent of which
neighbors it was co-batched with — bit-identical across schedules for the
dense/attention-free families (MoE capacity routing is batch-coupled by
construction).

    from repro.api import Engine, get_preset
    from repro.api.serving import Request, Scheduler
    eng = Engine(get_preset("serve_tiny"))
    reqs = [Request(rid=i, prompt=prompts[i]) for i in range(8)]
    report = Scheduler(eng).run(reqs)        # -> ServeReport
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any

import jax
import numpy as np

from repro.api.engine import Engine
from repro.api.report import RequestStats, ServeReport


@dataclass
class Request:
    """One serving request: a prompt of exactly serve.prompt_len token ids
    and an optional per-request generation budget (0 -> serve.gen; the
    cache is sized for at most serve.gen new tokens)."""

    rid: int
    prompt: Any                 # [prompt_len] token ids
    max_new_tokens: int = 0


class _Slot:
    """An in-flight request occupying one decode-batch row."""

    __slots__ = ("req", "stats", "limit", "next_pos", "last_tok", "t_admit")

    def __init__(self, req, stats, limit, next_pos, last_tok, t_admit):
        self.req, self.stats, self.limit = req, stats, limit
        self.next_pos, self.last_tok = next_pos, last_tok
        self.t_admit = t_admit


def _adopt_slots(cache, fresh, pairs):
    """Copy freshly prefilled cache rows into their assigned batch slots —
    one gather/scatter per leaf for the whole admission group. Every cache
    leaf carries the batch at dim 1; whole-row replacement also clears any
    stale KV / ring-buffer / SSM state from the slot's previous occupant."""
    srcs = np.array([s for s, _ in pairs])
    dsts = np.array([d for _, d in pairs])
    return jax.tree.map(lambda big, f: big.at[:, dsts].set(f[:, srcs]),
                        cache, fresh)


class Scheduler:
    def __init__(self, engine: Engine):
        plan = engine.plan
        if plan.serve is None:
            raise ValueError("the Scheduler drives serve Plans; Plan.serve "
                             "is unset — give the Plan a ServeSpec")
        if plan.arch.frontend != "none":
            raise ValueError(
                f"{plan.arch.name} is a stub-frontend architecture (inputs "
                f"are precomputed embeddings, not token ids); the request "
                f"scheduler feeds generated ids back — serve it through "
                f"Engine.generate() instead")
        self.engine = engine
        self.sv = plan.serve

    # ------------------------------------------------------------------
    def _pick_one(self, row, rid: int, k: int, key) -> int:
        """Next token for one request, keyed by (rid, k) so co-batching
        never changes a request's sample stream."""
        if self.sv.temperature == 0:
            return int(np.argmax(row))
        rk = jax.random.fold_in(jax.random.fold_in(key, rid), k)
        return int(jax.random.categorical(
            rk, np.asarray(row, np.float32) / self.sv.temperature))

    def run(self, requests, *, callback=None) -> ServeReport:
        """Serve `requests` (admitted FIFO) to completion. `callback(step,
        active_slots)` fires after every batched decode step."""
        eng, sv = self.engine, self.sv
        B, P = sv.max_batch, sv.prompt_len
        plan = eng.plan
        key = jax.random.PRNGKey(sv.sample_seed)
        queue = deque(requests)
        for r in queue:
            prompt = np.asarray(r.prompt)
            if prompt.shape != (P,):
                raise ValueError(
                    f"request {r.rid}: prompt shape {prompt.shape} != "
                    f"({P},); serve shapes are frozen in the Plan "
                    f"(ServeSpec.prompt_len)")
            if not 0 <= r.max_new_tokens <= sv.gen:
                raise ValueError(
                    f"request {r.rid}: max_new_tokens={r.max_new_tokens} "
                    f"must be in [0 (= the ServeSpec default), "
                    f"ServeSpec.gen={sv.gen}] — the cache is sized for "
                    f"gen new tokens")
        report = ServeReport(arch=plan.arch.name, backend=plan.run.backend,
                             max_batch=B)
        cache = eng.serve_cache()
        active: dict[int, _Slot] = {}
        free = list(range(B))
        step = 0
        t_start = time.monotonic()

        def retire(s: int, slot: _Slot):
            slot.stats.finished_step = step
            slot.stats.latency_s = time.monotonic() - slot.t_admit
            report.requests.append(slot.stats)
            free.append(s)
            free.sort()

        while queue or active:
            # ---- admit: longest-waiting requests into the lowest slots --
            if free and queue:
                admits = []
                while free and queue:
                    admits.append((queue.popleft(), free.pop(0)))
                prompts = np.zeros((B, P), np.int32)
                for j, (r, _) in enumerate(admits):
                    prompts[j] = np.asarray(r.prompt)
                t0 = time.monotonic()
                logits, fresh = eng.prefill(prompts)
                logits = np.asarray(logits)
                dt = time.monotonic() - t0
                report.prefill_s += dt
                cache = _adopt_slots(cache, fresh,
                                     [(j, s) for j, (_, s) in
                                      enumerate(admits)])
                for j, (r, s) in enumerate(admits):
                    tok = self._pick_one(logits[j], r.rid, 0, key)
                    stats = RequestStats(rid=r.rid, prompt_len=P,
                                         tokens=[tok], admitted_step=step,
                                         slot=s, prefill_s=dt)
                    slot = _Slot(r, stats, r.max_new_tokens or sv.gen,
                                 next_pos=P, last_tok=tok, t_admit=t0)
                    if len(stats.tokens) >= slot.limit:
                        retire(s, slot)
                    else:
                        active[s] = slot
            if not active:
                continue
            # ---- one batched decode step over every active slot ---------
            toks = np.zeros((B, 1), np.int32)
            pos = np.zeros(B, np.int32)
            for s, slot in active.items():
                toks[s, 0] = slot.last_tok
                pos[s] = slot.next_pos
            t0 = time.monotonic()
            logits, cache = eng.decode(toks, cache, pos)
            logits = np.asarray(logits)
            report.decode_s += time.monotonic() - t0
            report.decode_steps += 1
            report.slot_steps += len(active)
            step += 1
            # ---- advance / retire --------------------------------------
            for s in sorted(active):
                slot = active[s]
                tok = self._pick_one(logits[s], slot.req.rid,
                                     len(slot.stats.tokens), key)
                slot.stats.tokens.append(tok)
                slot.next_pos += 1
                slot.last_tok = tok
                if len(slot.stats.tokens) >= slot.limit:
                    del active[s]
                    retire(s, slot)
            if callback is not None:
                callback(step, len(active))
        report.wall_s = time.monotonic() - t_start
        report.requests.sort(key=lambda r: r.rid)
        return report


def serve(engine: Engine, requests, *, callback=None) -> ServeReport:
    """One-shot convenience: Scheduler(engine).run(requests)."""
    return Scheduler(engine).run(requests, callback=callback)
