"""The Engine: one facade executing any Plan.

fit() dispatches on the Plan alone:

  backend='threads' + WSP/ASP   threaded virtual-worker fleet against the
                                sharded parameter server (true async, D >= 0,
                                stragglers, periodic checkpoint, elastic
                                fail/rejoin)
  backend='threads' + BSP       the synchronous AllReduce loop (ring
                                all-reduce of every wave's deltas, simulated
                                straggler-gated clock)
  backend='spmd'                the jitted pipelined wave step over a
                                (data, stage, tp) mesh (D = 0)

Serve-mode Plans (Plan.serve = ServeSpec) run through
prefill()/decode()/generate() with the same dispatch rule:

  backend='spmd'                the pipelined serve steps
                                (core.wave.build_prefill_step /
                                build_decode_step) on a (1, stage, tp) mesh
  backend='threads'             the non-pipelined lm.forward_ref cache path
                                (the CPU correctness oracle)

All backends share model materialization, data loaders and report assembly
(TrainReport / ServeReport), and step()/save()/restore() complete the
surface: single-wave stepping for interactive use, atomic checkpointing,
exact resume.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.api.plan import Plan
from repro.api.report import ServeReport, Telemetry, TrainReport
from repro.api.sync import BSP, WSP
from repro.core.param_server import ParameterServer
from repro.obs import NULL_TRACER, emit_pipeline_ticks
from repro.obs.metrics import SECONDS_BOUNDS
from repro.data.pipeline import MarkovLM, ShardedLoader
from repro.dist import collectives
from repro.dist.topology import make_topology
from repro.dist.transport import SimulatedTransport
from repro.runtime.checkpoint import (latest_checkpoint, load_checkpoint,
                                      save_checkpoint)
from repro.runtime.virtual_worker import VirtualWorker


class Engine:
    """Executes a Plan. Model artifacts (params / wave step / optimizer) are
    built from the Plan's ArchConfig by default; tests and the legacy shims
    may inject prebuilt ones instead."""

    def __init__(self, plan: Plan, *, params=None, wave_step=None,
                 optimizer=None, tracer=None):
        if not isinstance(plan, Plan):
            raise TypeError(f"Engine wants a Plan, got {type(plan).__name__}")
        if plan.arch is None and (params is None or wave_step is None
                                  or optimizer is None):
            raise ValueError("Plan.arch is unset: inject params, wave_step "
                             "and optimizer, or give the Plan an ArchConfig")
        self.plan = plan
        # the tracer is runtime state, not Plan state: the same frozen Plan
        # runs traced or untraced. It cascades into the PS, transport,
        # workers and scheduler this engine builds.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._params = params
        self._wave_step = wave_step
        self._optimizer = optimizer
        self.ps: Optional[ParameterServer] = None
        self.topology = None
        self.workers: dict[str, VirtualWorker] = {}
        self.stop_event = threading.Event()
        self.supervisor = None     # FleetSupervisor when fault-supervised
        self._injector = None      # lazy FaultInjector from plan.faults
        self.report: Optional[TrainReport] = None
        self._source = None
        self._step_ctx = None      # lazy state for step()
        self._spmd = None          # lazy state for the spmd backend
        self._serve = None         # lazy state for the serve surface
        self._serve_paged = None   # lazy paged (CacheStore) serve executors
        self._step_offset = 0      # waves already in a restored checkpoint
        self._fleet_ran = False    # the threaded fleet is single-shot
        self._bsp_wave = 0         # waves the BSP loop has run (this engine)

    # ------------------------------------------------------------------
    # materialization
    # ------------------------------------------------------------------
    def _model_arch(self):
        """The arch whose parameter shapes this engine trains: the spmd
        backend re-factors stages/tp from the PartitionSpec (padded layer
        count can change), the threads backend uses the arch as declared."""
        if self.plan.run.backend != "spmd":
            return self.plan.arch
        import dataclasses as dc
        plan = self.plan
        arch = dc.replace(plan.arch, stages=plan.stages, tp=plan.tp)
        if plan.partition.num_microbatches:
            arch = dc.replace(
                arch, num_microbatches=plan.partition.num_microbatches)
        return arch

    def _ensure_model(self):
        from repro.core import wave
        from repro.models import lm
        from repro.optim import make_optimizer
        plan, run = self.plan, self.plan.run
        if self._optimizer is None:
            self._optimizer = make_optimizer(run.optimizer, run.lr,
                                             run.weight_decay)
        if self._params is None:
            self._params, _ = lm.init_params(self._model_arch(),
                                             jax.random.PRNGKey(run.seed))
        if plan.serve is not None:
            return                 # no wave step / loader on the serve path
        if self._wave_step is None and run.backend != "spmd":
            self._wave_step = wave.build_local_wave_step(
                plan.arch, plan.num_microbatches, self._optimizer)
        if self._source is None:
            self._source = MarkovLM(plan.vocab, seed=run.data_seed)

    def fault_injector(self):
        """The run's FaultInjector, built once from Plan.faults (None when
        the Plan carries no fault scenario). Shared by every seam — the
        transport, the PS and the Scheduler consult the same per-path /
        per-push / per-step counters."""
        if self._injector is None and self.plan.faults is not None:
            from repro.faults import FaultInjector
            self._injector = FaultInjector(
                self.plan.faults, time_scale=self.plan.cluster.time_scale)
        return self._injector

    def _ensure_ps(self, policy: WSP):
        if self.ps is not None:
            return
        plan = self.plan
        topo = plan.cluster.topology
        if isinstance(topo, str):
            topo = make_topology(topo, plan.cluster.num_vw)
        self.topology = topo
        injector = self.fault_injector()
        fpol = plan.fault_policy
        transport = (SimulatedTransport(topo,
                                        time_scale=plan.cluster.time_scale,
                                        tracer=self.tracer,
                                        injector=injector, policy=fpol)
                     if topo is not None else None)
        if transport is None and injector is not None:
            from repro.dist.transport import NullTransport
            transport = NullTransport(injector=injector, policy=fpol)
        self.ps = ParameterServer(
            self._params, D=policy.D,
            compression_ratio=plan.run.compression_ratio,
            codec=plan.run.codec, transport=transport,
            tracer=self.tracer, injector=injector)

    def _loader(self, i: int, num_vw: int) -> ShardedLoader:
        run = self.plan.run
        return ShardedLoader(self._source, run.batch, run.seq, i, num_vw,
                             seed=17)

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------
    def _tick_plan(self):
        """(schedule, ticks) of the Plan's modeled intra-VW pipeline, or
        None when the Plan carries no arch (injected wave steps have no
        declared stage structure to render)."""
        if not self.tracer.enabled or self.plan.arch is None:
            return None
        from repro.core import wave
        arch = self._model_arch()
        return wave.tick_schedule(arch.stages, self.plan.num_microbatches,
                                  overlap=self.plan.run.overlap)

    def attach_telemetry(self, report):
        """Record end-of-run gauges (staleness bound, per-link traffic) and
        attach the metrics snapshot to `report` — no-op untraced."""
        if not self.tracer.enabled:
            return report
        m = self.tracer.metrics
        policy = self.plan.sync
        if isinstance(policy, WSP):
            m.gauge_set("wsp/D", policy.D)
        if self.ps is not None:
            stats = self.ps.transport.stats()
            for name, b in stats["bytes_by_link"].items():
                m.gauge_set(f"link/{name}/bytes", b)
            for name, s in stats["seconds_by_link"].items():
                m.gauge_set(f"link/{name}/modeled_s", s)
        report.telemetry = Telemetry.from_metrics(m)
        return report

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------
    def fit(self, *, rejoin_failed_after: Optional[float] = None,
            callback: Optional[Callable] = None) -> TrainReport:
        """Run the Plan to completion and return its TrainReport.
        `callback(wave, loss, seconds)` is invoked per wave on backends with
        a central loop (bsp, spmd); the threaded fleet reports at the end."""
        plan = self.plan
        if plan.serve is not None:
            raise ValueError("this Plan describes serving (Plan.serve is "
                             "set); run it through Engine.generate() — "
                             "fit() trains")
        if plan.run.resume and plan.run.ckpt_dir:
            self.restore()
        with self.tracer.span("engine", "fit", backend=plan.run.backend,
                              sync=plan.sync.describe()):
            if plan.run.backend == "spmd":
                if rejoin_failed_after is not None:
                    raise ValueError("elastic rejoin is a feature of the "
                                     "threaded parameter-server fleet; the "
                                     "jitted spmd backend has no workers to "
                                     "rejoin")
                self.report = self._fit_spmd(callback=callback)
            else:
                self.report = plan.sync.execute(
                    self, rejoin_failed_after=rejoin_failed_after,
                    callback=callback)
        return self.attach_telemetry(self.report)

    def step(self):
        """One synchronous wave (single-worker semantics on the threads
        backend, one jitted step on spmd). Returns the wave's loss."""
        if self.plan.serve is not None:
            raise ValueError("step() drives a training wave; this Plan "
                             "serves — use prefill()/decode()/generate()")
        if self.plan.run.backend == "spmd":
            self._ensure_spmd()
            with self.tracer.span("engine", "step",
                                  wave=self._spmd["wave"]):
                return self._spmd_step()
        policy = self.plan.sync
        if not isinstance(policy, WSP):
            raise ValueError(
                f"step() drives the parameter-server runtime and supports "
                f"WSP/ASP policies (or the spmd backend); this Plan's "
                f"{policy.describe()} runs only through fit()")
        self._ensure_model()
        self._ensure_ps(policy)
        if self._step_ctx is None:
            wid = "vw0"
            self.ps.register(wid)
            self._step_ctx = {
                "wid": wid,
                "loader": self._loader(0, 1),
                "opt_state": self._optimizer.init(self.ps.pull()),
                "params": self.ps.pull(wid),
            }
        ctx = self._step_ctx
        wid = ctx["wid"]
        # raises the typed GateTimeout if the gate never opens
        self.ps.gate(wid, timeout=self.plan.fault_policy.gate_timeout_s)
        with self.tracer.span("engine", "step"):
            x, y = ctx["loader"].next()
            deltas, ctx["opt_state"], loss = self._wave_step(
                ctx["params"], ctx["opt_state"], x, y)
            wave = self.ps.push_wave(wid, deltas)
        # mirror VirtualWorker's weight handling so fit() and step() agree:
        # local weights see their own wave immediately, w_global is pulled
        # every pull_every waves
        if policy.pull_every != 1:
            ctx["params"] = jax.tree.map(
                np.add, ctx["params"], jax.tree.map(np.asarray, deltas))
        if policy.pull_every and wave % policy.pull_every == 0:
            ctx["params"] = self.ps.pull(wid)
        return float(loss)

    def save(self, ckpt_dir: Optional[str] = None) -> str:
        """Checkpoint the full training state atomically (PS weights + WSP
        clocks are snapshotted under the push lock, so an in-flight async
        push is either entirely in the checkpoint or entirely out)."""
        ckpt_dir = ckpt_dir or self.plan.run.ckpt_dir
        if not ckpt_dir:
            raise ValueError("no checkpoint directory: set run.ckpt_dir or "
                             "pass one to save()")
        if self.ps is not None:
            params, meta = self.ps.checkpoint_state()
            step = min(meta["clocks"].values()) if meta["clocks"] else \
                meta["push_count"]
            return save_checkpoint(ckpt_dir, self._step_offset + step,
                                   {"params": params}, meta)
        if self._spmd is not None:
            step = self._step_offset + self._spmd["wave"]
            params = jax.tree.map(np.asarray, self._spmd["params"])
            return save_checkpoint(ckpt_dir, step, {"params": params},
                                   {"wave": step})
        self._ensure_model()
        step = self._step_offset + self._bsp_wave
        return save_checkpoint(ckpt_dir, step, {"params": self._params},
                               {"wave": step})

    def restore(self, path: Optional[str] = None) -> Optional[dict]:
        """Load the latest (or given) checkpoint's weights into the engine;
        returns the checkpoint meta, or None if there is nothing to restore.
        Worker clocks restart at zero (max_waves counts waves of this run),
        but new checkpoints continue the restored step numbering so a later
        latest_checkpoint() never resolves to pre-resume state."""
        path = path or (latest_checkpoint(self.plan.run.ckpt_dir)
                        if self.plan.run.ckpt_dir else None)
        if path is None:
            return None
        self._ensure_model()
        out, meta = load_checkpoint(path, {"params": self._params})
        self._step_offset = int(meta.get("step", 0))
        self._params = out["params"]
        if self.ps is not None:
            leaves = [np.asarray(l).astype(np.float32).ravel()
                      for l in jax.tree.leaves(self._params)]
            self.ps.load_state_dict({"flat": leaves,
                                     "clocks": dict(self.ps.clock.state.clocks),
                                     "push_count": self.ps.push_count})
        if self._spmd is not None:
            # re-place with the mesh sharding (a bare device_put would
            # commit the whole tree to one device) and drop optimizer
            # moments computed for the pre-restore weights
            st = self._spmd
            st["params"] = self._shard_params(st["mesh"], st["pspecs"],
                                              self._params)
            from repro.compat import set_mesh
            with set_mesh(st["mesh"]):
                st["opt_state"] = self._optimizer.init(st["params"])
        if self._serve is not None:
            st = self._serve
            st["params"] = (self._shard_params(st["mesh"], st["pspecs"],
                                               self._params)
                            if st["mode"] == "spmd" else self._params)
        return meta

    # ------------------------------------------------------------------
    # serve surface: prefill / decode / generate (Plan.serve = ServeSpec)
    # ------------------------------------------------------------------
    def _require_serve(self, what: str):
        if self.plan.serve is None:
            raise ValueError(f"{what}() serves requests; Plan.serve is "
                             f"unset — give the Plan a ServeSpec (train "
                             f"Plans run through fit())")

    def _serve_dtypes(self):
        from repro.models import lm
        run, sv = self.plan.run, self.plan.serve
        return lm.serve_dtypes(run.compute_dtype, sv.cache_dtype)

    def _ensure_serve(self):
        """Build the serve executors the Plan names: the pipelined mesh
        steps (backend='spmd') or the forward_ref cache path (threads)."""
        if self._serve is not None:
            return
        from repro.models import lm
        plan, run, sv = self.plan, self.plan.run, self.plan.serve
        self._ensure_model()
        cfg = self._model_arch()
        _, cache_dt = self._serve_dtypes()

        if run.backend != "spmd":
            pre_fn, dec_fn = _ref_serve_steps(cfg, sv.kernel_backend)
            self._serve = {"mode": "ref", "cfg": cfg, "params": self._params,
                           "prefill": jax.jit(pre_fn),
                           "decode": jax.jit(dec_fn),
                           "cache_dt": cache_dt, "mesh": None}
            return

        from repro.compat import set_mesh
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.core import wave
        from repro.launch.mesh import make_mesh_auto
        from jax.sharding import NamedSharding, PartitionSpec as P

        dsz, ssz, tsz = plan.partition.data, plan.stages, plan.tp
        needed = dsz * ssz * tsz
        if len(jax.devices()) < needed:
            raise RuntimeError(
                f"the spmd serve path needs {needed} devices "
                f"(data*stages*tp = {dsz}*{ssz}*{tsz}) but jax sees "
                f"{len(jax.devices())}; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={needed} before "
                f"jax initializes")
        mesh = make_mesh_auto((dsz, ssz, tsz), ("data", "stage", "tp"))
        pspecs = lm.param_specs(cfg)
        common = dict(arch=cfg, optimizer=run.optimizer, lr=run.lr,
                      weight_decay=run.weight_decay,
                      compute_dtype=run.compute_dtype,
                      cache_dtype=sv.cache_dtype, overlap=run.overlap,
                      kernel_backend=sv.kernel_backend)
        rc_pre = RunConfig(shape=ShapeConfig("serve_prefill", sv.prompt_len,
                                             sv.max_batch, "prefill"),
                           **common)
        rc_dec = RunConfig(shape=ShapeConfig("serve_decode", sv.max_len,
                                             sv.max_batch, "decode"),
                           **common)
        pre_step, _, _ = wave.build_prefill_step(rc_pre, mesh,
                                                 cache_len=sv.max_len)
        dec_step, _, cspecs = wave.build_decode_step(rc_dec, mesh,
                                                     pos_per_row=True)
        p_sh = self._shard_params(mesh, pspecs, self._params)
        with set_mesh(mesh):
            csh = jax.tree.map(
                lambda s: NamedSharding(mesh, s), cspecs,
                is_leaf=lambda x: isinstance(x, P))

        def pre_fn(params, inputs, cache):
            return pre_step(params, {"inputs": inputs, "cache": cache})

        def dec_fn(params, inputs, cache, pos):
            return dec_step(params, {"inputs": inputs, "cache": cache,
                                     "pos": pos})

        self._serve = {"mode": "spmd", "cfg": cfg, "params": p_sh,
                       "prefill": jax.jit(pre_fn),
                       "decode": jax.jit(dec_fn), "mesh": mesh,
                       "pspecs": pspecs, "cache_sharding": csh,
                       "cache_dt": cache_dt}

    def _ensure_serve_store(self):
        """Build the paged (CacheStore-backed) serve executors: a variable-
        length prefill that scatters K/V pages through the block table, and
        a per-row-position decode over the paged tree. Compiled separately
        from the aligned generate() path (which keeps the contiguous
        reference layout)."""
        if getattr(self, "_serve_paged", None) is not None:
            return
        from repro.serve import cache as cache_lib
        self._ensure_serve()
        plan, run, sv = self.plan, self.plan.run, self.plan.serve
        st = self._serve
        cfg = st["cfg"]
        layout = cache_lib.make_layout(sv.max_batch, sv.max_len,
                                       page_size=sv.page_size,
                                       max_pages=sv.max_pages)

        if st["mode"] != "spmd":
            pre_fn, dec_fn = _ref_paged_steps(cfg, sv.kernel_backend)
            self._serve_paged = {"layout": layout, "shardings": None,
                                 "prefill": jax.jit(pre_fn),
                                 "decode": jax.jit(dec_fn)}
            return

        from repro.compat import set_mesh
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.core import wave
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = st["mesh"]
        common = dict(arch=cfg, optimizer=run.optimizer, lr=run.lr,
                      weight_decay=run.weight_decay,
                      compute_dtype=run.compute_dtype,
                      cache_dtype=sv.cache_dtype, overlap=run.overlap,
                      kernel_backend=sv.kernel_backend)
        rc_pre = RunConfig(shape=ShapeConfig("serve_prefill", sv.prompt_len,
                                             sv.max_batch, "prefill"),
                           **common)
        rc_dec = RunConfig(shape=ShapeConfig("serve_decode", sv.max_len,
                                             sv.max_batch, "decode"),
                           **common)
        pre_step, _, _ = wave.build_prefill_step(rc_pre, mesh, layout=layout,
                                                 var_len=True)
        dec_step, _, cspecs = wave.build_decode_step(rc_dec, mesh,
                                                     pos_per_row=True,
                                                     layout=layout)
        with set_mesh(mesh):
            shardings = jax.tree.map(
                lambda s: NamedSharding(mesh, s), cspecs,
                is_leaf=lambda x: isinstance(x, P))

        def pre_fn(params, inputs, lens, cache):
            return pre_step(params, {"inputs": inputs, "cache": cache,
                                     "lens": lens})

        def dec_fn(params, inputs, cache, pos):
            return dec_step(params, {"inputs": inputs, "cache": cache,
                                     "pos": pos})

        self._serve_paged = {"layout": layout, "shardings": shardings,
                             "prefill": jax.jit(pre_fn),
                             "decode": jax.jit(dec_fn)}

    def serve_store(self):
        """A fresh CacheStore (empty page pool + per-slot state) for this
        Plan's serve shapes, placed for its backend. The Scheduler
        allocates pages at admission and frees them at retirement."""
        from repro.serve import cache as cache_lib
        self._require_serve("serve_store")
        self._ensure_serve_store()
        st, pg = self._serve, self._serve_paged
        return cache_lib.CacheStore(st["cfg"], pg["layout"],
                                    dtype=st["cache_dt"],
                                    shardings=pg["shardings"])

    def serve_cache(self):
        """A blank (all-slots-empty) serve cache for max_batch requests of
        up to serve.max_len positions, placed for this Plan's backend."""
        from repro.models import lm
        self._require_serve("serve_cache")
        self._ensure_serve()
        st, sv = self._serve, self.plan.serve
        cache = lm.init_cache(st["cfg"], sv.max_batch, sv.max_len,
                              dtype=st["cache_dt"])
        if st["mode"] == "spmd":
            cache = jax.device_put(cache, st["cache_sharding"])
        return cache

    def prefill(self, prompts):
        """Prefill a full batch of prompts into a fresh cache.

        prompts: [max_batch, prompt_len] token ids (or [.., .., d_model]
        embeddings for frontend archs). Returns (last_logits [B, vocab],
        cache) — the logits of the final prompt position, i.e. the
        distribution of the first generated token."""
        import jax.numpy as jnp
        self._require_serve("prefill")
        self._ensure_serve()
        st, sv = self._serve, self.plan.serve
        prompts = jnp.asarray(prompts)
        if prompts.shape[:2] != (sv.max_batch, sv.prompt_len):
            raise ValueError(
                f"prompts {prompts.shape} disagree with the frozen serve "
                f"shapes [{sv.max_batch}, {sv.prompt_len}]; pad the batch "
                f"to max_batch (ServeSpec shapes compile once)")
        with self.tracer.span("engine", "prefill", batch=sv.max_batch):
            logits, cache = st["prefill"](st["params"], prompts,
                                          self.serve_cache())
            if self.tracer.enabled:      # span measures compute, not dispatch
                jax.block_until_ready(logits)
        return logits[:, -1], cache

    def prefill_into(self, store, prompts, lens, slots, skip_pages=None):
        """Prefill a batch of (possibly variable-length, right-padded)
        prompts directly into `store`'s page pool.

        prompts [max_batch, prompt_len] token ids with rows 0..len(slots)-1
        carrying real requests; lens [max_batch] per-row prompt lengths;
        slots the store slot assigned to each live row. K/V pages scatter
        through the block table in place; freshly computed per-slot state
        (ring buffers, SSM/RWKV state) is adopted into the assigned slots.
        Returns each live row's last-real-position logits [max_batch,
        vocab].

        skip_pages[j] (optional, per live row) skips *writing* row j's
        first N KV pages: they hold a shared prefix the memory manager
        mapped from the index, already filled with bit-identical K/V.
        The row's compute still spans the whole prompt — per-slot state
        (rings, SSM/RWKV recurrences) is not paged and must be rebuilt
        from position 0 — so prefilling "only the suffix" means only the
        suffix's pages are written; the matched pages' recomputed K/V
        routes to the trash page."""
        import jax.numpy as jnp
        from repro.serve.cache import CacheStore
        self._require_serve("prefill_into")
        self._ensure_serve_store()
        if not isinstance(store, CacheStore):
            raise TypeError(f"prefill_into writes a CacheStore, got "
                            f"{type(store).__name__}")
        st, pg, sv = self._serve, self._serve_paged, self.plan.serve
        prompts = jnp.asarray(prompts)
        if prompts.shape[:2] != (sv.max_batch, sv.prompt_len):
            raise ValueError(
                f"prompts {prompts.shape} disagree with the frozen serve "
                f"shapes [{sv.max_batch}, {sv.prompt_len}] (pad short "
                f"prompts on the right; lens carries the real lengths)")
        lens = jnp.asarray(lens, jnp.int32)
        with self.tracer.span("engine", "prefill", rows=len(slots)):
            logits, out = pg["prefill"](st["params"], prompts, lens,
                                        store.prefill_input(
                                            slots, skip_pages=skip_pages))
            if self.tracer.enabled:
                jax.block_until_ready(logits)
        store.append_rows(out, [(j, s) for j, s in enumerate(slots)])
        return logits[:, -1]

    def decode(self, tokens, cache, pos):
        """One decode position for the whole batch.

        tokens [B, 1] ids (or [B, 1, d] embeddings); pos a scalar (aligned
        batch) or [B] vector (continuous batching: each row at its own
        depth); cache the contiguous tree from prefill() — or a CacheStore,
        which routes through the paged decode step and is updated in
        place. Returns (logits [B, vocab], cache)."""
        import jax.numpy as jnp
        from repro.serve.cache import CacheStore
        self._require_serve("decode")
        sv = self.plan.serve
        pos = jnp.asarray(pos, jnp.int32)
        if pos.ndim == 0:
            # one trace serves both aligned and per-row decode
            pos = jnp.broadcast_to(pos, (sv.max_batch,))
        if isinstance(cache, CacheStore):
            self._ensure_serve_store()
            st, pg = self._serve, self._serve_paged
            with self.tracer.span("engine", "decode"):
                logits, out = pg["decode"](st["params"], jnp.asarray(tokens),
                                           cache.tree, pos)
                if self.tracer.enabled:
                    jax.block_until_ready(logits)
            cache.update(out)
            return logits[:, -1], cache
        self._ensure_serve()
        st = self._serve
        with self.tracer.span("engine", "decode"):
            logits, cache = st["decode"](st["params"], jnp.asarray(tokens),
                                         cache, pos)
            if self.tracer.enabled:
                jax.block_until_ready(logits)
        return logits[:, -1], cache

    def _serve_prompts(self, key):
        """Deterministic synthetic prompts (token ids, or stub embeddings
        for frontend archs) when the caller brings none."""
        import jax.numpy as jnp
        from repro.models import frontend
        sv, cfg = self.plan.serve, self.plan.arch
        if cfg.frontend != "none":
            return frontend.stub_embeddings(cfg, key, sv.max_batch,
                                            sv.prompt_len)
        return jax.random.randint(key, (sv.max_batch, sv.prompt_len), 0,
                                  cfg.vocab_size, dtype=jnp.int32)

    def generate(self, prompts=None, *, callback=None) -> ServeReport:
        """Run the Plan's full serve scenario on one aligned batch: prefill
        max_batch prompts, then gen greedy/sampled decode positions.
        Returns a ServeReport with `tokens` [B, gen]. `callback(step,
        tokens)` is invoked per decode position."""
        import jax.numpy as jnp
        from repro.models import frontend
        self._require_serve("generate")
        self._ensure_serve()
        plan, sv, cfg = self.plan, self.plan.serve, self.plan.arch
        key = jax.random.PRNGKey(sv.sample_seed)
        if prompts is None:
            prompts = self._serve_prompts(key)
        report = ServeReport(arch=cfg.name, backend=plan.run.backend,
                             max_batch=sv.max_batch)
        t_tr = self.tracer.now()
        t_start = time.monotonic()
        logits, cache = self.prefill(prompts)
        jax.block_until_ready(logits)
        report.prefill_s = time.monotonic() - t_start
        report.prefill_calls = 1
        self.tracer.metrics.observe("serve/ttft_s", report.prefill_s)
        tok = _pick(logits, sv.temperature, jax.random.fold_in(key, 0))
        toks = [tok]
        if callback is not None:
            callback(0, tok)
        for t in range(1, sv.gen):
            if cfg.frontend != "none":
                # stub frontends embed generated ids via a fixed projection
                x = frontend.stub_embeddings(cfg, jax.random.fold_in(key, t),
                                             sv.max_batch, 1)
            else:
                x = toks[-1][:, None]
            t0 = time.monotonic()
            logits, cache = self.decode(x, cache,
                                        jnp.int32(sv.prompt_len + t - 1))
            jax.block_until_ready(logits)
            report.decode_s += time.monotonic() - t0
            report.decode_steps += 1
            tok = _pick(logits, sv.temperature, jax.random.fold_in(key, t))
            toks.append(tok)
            if callback is not None:
                callback(t, tok)
        report.tokens = np.stack([np.asarray(t) for t in toks], axis=1)
        report.wall_s = time.monotonic() - t_start
        self.tracer.add_span("engine", "generate", t_tr, self.tracer.now(),
                             gen=sv.gen, batch=sv.max_batch)
        return self.attach_telemetry(report)

    # ------------------------------------------------------------------
    # threads backend: WSP / ASP (policy.execute lands here)
    # ------------------------------------------------------------------
    def _make_worker(self, i: int, wid: str, policy: WSP, *,
                     successor: bool = False) -> VirtualWorker:
        cl = self.plan.cluster
        speeds = cl.speeds or (0.0,) * cl.num_vw
        straggle = cl.straggle_fns or (None,) * cl.num_vw
        injector = self.fault_injector()
        # a rejoined successor does not replay its predecessor's death: the
        # crash / fail_at anchors belong to the original incarnation only
        # (slowdown persists — the *node* is slow, not the process)
        crash_at = None
        if injector is not None and not successor:
            crash_at = injector.crash_wave(i)
        return VirtualWorker(
            wid, self.ps, self._wave_step, self._loader(i, cl.num_vw),
            self._optimizer.init(self.ps.pull()),
            max_waves=self.plan.run.max_waves,
            pull_every=policy.pull_every,
            slowdown=speeds[i], straggle_fn=straggle[i],
            stop_event=self.stop_event,
            fail_at_wave=None if successor else cl.fail_map().get(i),
            async_push=policy.async_push,
            tracer=self.tracer, D=policy.D, tick_plan=self._tick_plan(),
            injector=injector, vw_index=i, crash_at=crash_at,
            gate_timeout_s=self.plan.fault_policy.gate_timeout_s)

    def _fit_threaded(self, policy: WSP, *,
                      rejoin_failed_after: Optional[float] = None,
                      callback: Optional[Callable] = None) -> TrainReport:
        del callback       # per-worker losses are reported at the end
        if self._fleet_ran:
            # a fresh fleet would find the PS clocks already at max_waves
            # and exit with an empty report — fail loudly instead
            raise RuntimeError(
                "this Engine's worker fleet already ran; build a new Engine "
                "(with run.resume=True to continue from a checkpoint)")
        self._fleet_ran = True
        self._ensure_model()
        self._ensure_ps(policy)
        plan, run = self.plan, self.plan.run
        num_vw = plan.cluster.num_vw
        t0 = time.monotonic()
        # register the whole initial fleet before any worker thread runs:
        # a late-registering worker would otherwise start at the already-
        # advanced global clock and silently skip its first waves
        # (VirtualWorker.run's own register() is then an idempotent no-op,
        # since this worker's clock-0 entry pins the global minimum)
        for i in range(num_vw):
            self.ps.register(f"vw{i}")
        for i in range(num_vw):
            wid = f"vw{i}"
            self.workers[wid] = self._make_worker(i, wid, policy)
            self.workers[wid].start()
        ckpt_step = 0
        fpol = plan.fault_policy
        if rejoin_failed_after is not None:
            # the legacy knob, promoted onto the first-class FaultPolicy:
            # rejoin each failed worker once, this many seconds after its
            # eviction was recorded
            import dataclasses as dc
            fpol = dc.replace(fpol, rejoin_delay_s=rejoin_failed_after,
                              rejoin_max=max(1, fpol.rejoin_max))
        supervise = fpol.evict_lag > 0 or fpol.rejoins \
            or plan.faults is not None
        if supervise:
            from repro.faults import FleetSupervisor

            def spawn(i: int, new_wid: str):
                nw = self._make_worker(i, new_wid, policy, successor=True)
                self.workers[new_wid] = nw
                nw.start()
                return nw

            self.supervisor = FleetSupervisor(
                self.ps, self.workers, fpol, spawn=spawn,
                topology=self.topology, tracer=self.tracer)
        periodic = bool(run.ckpt_dir and run.ckpt_every) or supervise
        if not periodic:
            # nothing to supervise: block on the (fixed) worker set directly
            for w in list(self.workers.values()):
                w.join()
        tick = min(0.25, fpol.heartbeat_every_s) if supervise else 0.25
        while periodic and (
                any(w.is_alive() for w in self.workers.values())
                or (self.supervisor is not None
                    and self.supervisor.pending_rejoin())):
            # wake on wave completion / worker exit rather than busy-polling
            self.ps.push_event.wait(timeout=tick)
            self.ps.push_event.clear()
            if self.supervisor is not None:
                self.supervisor.poll()
            # periodic checkpoint (PS + clocks, snapshotted atomically)
            if run.ckpt_dir and run.ckpt_every:
                gc = self.ps.clock.global_clock()
                if gc >= ckpt_step + run.ckpt_every:
                    ckpt_step = gc
                    params, meta = self.ps.checkpoint_state()
                    save_checkpoint(run.ckpt_dir, self._step_offset + gc,
                                    {"params": params}, meta)
        if run.ckpt_dir and run.ckpt_every:
            # final checkpoint: the loop wakes on push events and may exit
            # the moment the last worker dies, before the last periodic
            # write — resume must still see the end-of-run state
            gc = self.ps.clock.global_clock()
            if gc > ckpt_step:
                params, meta = self.ps.checkpoint_state()
                save_checkpoint(run.ckpt_dir, self._step_offset + gc,
                                {"params": params}, meta)
        report = TrainReport()
        for wid, w in self.workers.items():
            for t, l in zip(w.metrics.wall_clock, w.metrics.losses):
                report.losses.append((t, wid, l))
            report.waves += w.metrics.waves
            report.overlap_seconds += w.metrics.overlap_seconds
            report.push_wait_seconds += w.metrics.push_wait_seconds
            report.gate_timeouts += w.metrics.gate_timeouts
            if w.failed:
                report.crashes += 1
        report.waves_requested = run.max_waves * num_vw
        report.wall_s = time.monotonic() - t0
        report.wait_seconds = dict(self.ps.clock.wait_seconds)
        report.bytes_pushed = self.ps.bytes_pushed
        report.bytes_wire = self.ps.bytes_wire
        report.comm_seconds = self.ps.comm_seconds
        report.comm = self.ps.transport.stats()
        report.late_pushes = self.ps.late_pushes
        report.ps_stalls = self.ps.ps_stalls
        report.drops = report.comm.get("drops", 0)
        report.retries = report.comm.get("retries", 0)
        if self.supervisor is not None:
            report.evictions = [(e.wid, e.at_clock, e.reason, e.rejoined)
                                for e in self.supervisor.evictions]
            report.rejoins = list(self.supervisor.rejoins)
        # fail loudly on silent degradation: a run that timed out at the
        # staleness gate, or lost a worker to a typed fault without a
        # successor taking over, did NOT do the work the Plan requested.
        # FaultPolicy(allow_degraded=True) opts into getting the (counter-
        # annotated) report back instead.
        if not fpol.allow_degraded:
            degraded = []
            for wid, w in self.workers.items():
                if w.metrics.gate_timeouts:
                    degraded.append(f"{wid}: staleness gate timed out")
                elif w.error is not None and (wid + "r") not in self.workers:
                    degraded.append(f"{wid}: {w.error}")
            if degraded:
                from repro.faults import DegradedRunError
                raise DegradedRunError(
                    "run completed degraded (set FaultPolicy.allow_degraded "
                    "to accept): " + "; ".join(degraded), report=report)
        return report

    # ------------------------------------------------------------------
    # threads backend: BSP (policy.execute lands here)
    # ------------------------------------------------------------------
    def _fit_bsp(self, policy: BSP, *,
                 rejoin_failed_after: Optional[float] = None,
                 callback: Optional[Callable] = None) -> TrainReport:
        """Synchronous AllReduce DP: every wave, all VWs' deltas are reduced
        via an emulated ring all-reduce and applied to one global copy.

        Wall clock is a *simulated* straggler-gated time: the VW steps run
        sequentially on this host, so each wave is charged the max over VWs
        of (measured compute + simulated slowdown) plus the topology-
        predicted all-reduce time, and all of a wave's losses share that one
        timestamp."""
        if rejoin_failed_after is not None:
            raise ValueError("elastic rejoin is a parameter-server feature; "
                             "BSP has no PS to rejoin against")
        self._ensure_model()
        plan, run = self.plan, self.plan.run
        num_vw = plan.cluster.num_vw
        topo = plan.cluster.topology
        if isinstance(topo, str):
            topo = make_topology(topo, num_vw)
        self.topology = topo
        names = [f"vw{i}" for i in range(num_vw)]
        loaders = [self._loader(i, num_vw) for i in range(num_vw)]
        params = jax.tree.map(np.asarray, self._params)
        opt_states = [self._optimizer.init(self._params)
                      for _ in range(num_vw)]
        speeds = plan.cluster.speeds or (0.0,) * num_vw
        report = TrainReport()
        waits = {f"vw{i}": 0.0 for i in range(num_vw)}
        tr = self.tracer
        sim_t = 0.0
        for wave_i in range(run.max_waves):
            deltas_all, losses, per_vw_t = [], [], []
            t_wave = 0.0
            with tr.span("engine", "bsp_wave", wave=wave_i):
                for i in range(num_vw):
                    x, y = loaders[i].next()
                    tw0 = time.monotonic()
                    with tr.span(f"vw{i}", "wave", wave=wave_i):
                        deltas, opt_states[i], loss = self._wave_step(
                            params, opt_states[i], x, y)
                    t_i = time.monotonic() - tw0 + speeds[i]
                    per_vw_t.append(t_i)
                    t_wave = max(t_wave, t_i)
                    deltas_all.append(deltas)
                    losses.append(float(loss))
                mean_delta, coll_s = collectives.ring_allreduce(
                    deltas_all, topology=topo, workers=names,
                    average=policy.average)
            # the BSP barrier: each VW waits for the wave's slowest
            for i, t_i in enumerate(per_vw_t):
                waits[f"vw{i}"] += t_wave - t_i
                tr.metrics.observe("train/wait_s", t_wave - t_i,
                                   bounds=SECONDS_BOUNDS)
            params = jax.tree.map(np.add, params, mean_delta)
            nbytes = sum(np.asarray(l).nbytes
                         for l in jax.tree.leaves(mean_delta))
            report.bytes_pushed += nbytes * num_vw
            # ring wire traffic: each VW moves 2(N-1)/N of the vector per wave
            report.bytes_wire += int(2 * (num_vw - 1) * nbytes) \
                if num_vw > 1 else 0
            report.comm_seconds += coll_s
            sim_t += t_wave + coll_s
            for i, l in enumerate(losses):
                report.losses.append((sim_t, f"vw{i}", l))
            report.waves += num_vw
            if callback is not None:
                callback(wave_i, float(np.mean(losses)), t_wave + coll_s)
            self._params = params
            self._bsp_wave += 1
            if run.ckpt_dir and run.ckpt_every and \
                    ((wave_i + 1) % run.ckpt_every == 0
                     or wave_i + 1 == run.max_waves):
                step = self._step_offset + self._bsp_wave
                save_checkpoint(run.ckpt_dir, step, {"params": params},
                                {"wave": step})
        report.wall_s = sim_t
        report.wait_seconds = waits
        self._params = params
        return report

    # ------------------------------------------------------------------
    # spmd backend: the jitted pipelined wave step
    # ------------------------------------------------------------------
    def _ensure_spmd(self):
        if self._spmd is not None:
            return
        from repro.compat import set_mesh
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.core import wave
        from repro.launch.mesh import make_mesh_auto
        from repro.models import lm

        plan, run = self.plan, self.plan.run
        dsz, ssz, tsz = plan.partition.data, plan.stages, plan.tp
        needed = dsz * ssz * tsz
        if len(jax.devices()) < needed:
            raise RuntimeError(
                f"the spmd backend needs {needed} devices "
                f"(data*stages*tp = {dsz}*{ssz}*{tsz}) but jax sees "
                f"{len(jax.devices())}; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={needed} before "
                f"jax initializes (launch/train.py --devices does this)")
        mesh = make_mesh_auto((dsz, ssz, tsz), ("data", "stage", "tp"))
        self._ensure_model()               # params for the stage-replaced arch
        arch = self._model_arch()
        pspecs = lm.param_specs(arch)
        shape = plan.shape or ShapeConfig("plan", run.seq, run.batch * dsz,
                                          "train")
        rc = RunConfig(arch=arch, shape=shape, optimizer=run.optimizer,
                       lr=run.lr, weight_decay=run.weight_decay,
                       compute_dtype=run.compute_dtype,
                       loss_chunk=min(run.loss_chunk, run.seq),
                       overlap=run.overlap)
        step, _ = wave.build_train_step(rc, mesh)
        loader = ShardedLoader(self._source, shape.global_batch, run.seq,
                               0, 1)
        p_sh = self._shard_params(mesh, pspecs, self._params)
        with set_mesh(mesh):
            opt_state = self._optimizer.init(p_sh)
        self._spmd = {
            "mesh": mesh, "arch": arch, "loader": loader, "pspecs": pspecs,
            "params": p_sh, "opt_state": opt_state,
            "jstep": jax.jit(step, donate_argnums=(0, 1)), "wave": 0,
        }

    @staticmethod
    def _shard_params(mesh, pspecs, params):
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.compat import set_mesh
        with set_mesh(mesh):
            return jax.device_put(params, jax.tree.map(
                lambda s: NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P)))

    def _spmd_step(self) -> float:
        import jax.numpy as jnp

        from repro.compat import set_mesh
        st = self._spmd
        x, y = st["loader"].next()
        # the ambient-mesh context is scoped per call rather than held open
        # for the engine's lifetime, so unrelated jax work in this process
        # never runs under a stale mesh
        with set_mesh(st["mesh"]):
            st["params"], st["opt_state"], m = st["jstep"](
                st["params"], st["opt_state"],
                {"inputs": jnp.asarray(x), "labels": jnp.asarray(y)})
        st["wave"] += 1
        return float(m["loss"])

    def _fit_spmd(self, *, callback: Optional[Callable] = None
                  ) -> TrainReport:
        self._ensure_spmd()
        run = self.plan.run
        report = TrainReport()
        tick_plan = self._tick_plan()
        t_start = time.monotonic()
        for w in range(run.max_waves):
            t0 = time.monotonic()
            with self.tracer.span("engine", "wave", wave=w):
                loss = self._spmd_step()
            dt = time.monotonic() - t0
            if tick_plan is not None:
                # the jitted step is opaque to host tracing; render the
                # Plan's pipeline schedule scaled into the measured window
                sched, ticks = tick_plan
                emit_pipeline_ticks(self.tracer, "spmd", sched, ticks,
                                    t0, t0 + dt)
            report.losses.append((time.monotonic() - t_start, "spmd", loss))
            report.waves += 1
            if callback is not None:
                callback(w, loss, dt)
            if run.ckpt_dir and run.ckpt_every and \
                    ((w + 1) % run.ckpt_every == 0
                     or w + 1 == run.max_waves):
                # the final wave checkpoints even off-cadence: resume must
                # see the end-of-run state (matches the threads backend)
                self.save()
        report.wall_s = time.monotonic() - t_start
        # the jitted step has no host-visible sync gate; the key exists so
        # downstream code reads one wait_seconds schema across backends
        report.wait_seconds = {"spmd": 0.0}
        self._params = jax.tree.map(np.asarray, self._spmd["params"])
        return report


# ---------------------------------------------------------------------------
# serve helpers (module level so jit caches don't capture the Engine)
# ---------------------------------------------------------------------------
def _ref_serve_steps(cfg, kernel_backend="ref"):
    """The non-pipelined forward_ref cache path: (prefill_fn, decode_fn),
    each jittable. With kernel_backend="ref" this is the serve correctness
    oracle the pipelined mesh steps (and the Pallas kernel backends) are
    parity-tested against; "interpret"/"tpu" route the attention/SSM mixes
    through repro.kernels."""
    from repro.models import lm

    def pre_fn(params, prompts, cache):
        hid, cache, _ = lm.forward_ref(cfg, params, prompts, mode="prefill",
                                       cache=cache,
                                       kernel_backend=kernel_backend)
        return lm.logits_ref(cfg, params, hid[:, -1:]), cache

    def dec_fn(params, tokens, cache, pos):
        hid, cache, _ = lm.forward_ref(cfg, params, tokens, mode="decode",
                                       cache=cache, pos=pos,
                                       kernel_backend=kernel_backend)
        return lm.logits_ref(cfg, params, hid), cache

    return pre_fn, dec_fn


def _ref_paged_steps(cfg, kernel_backend="ref"):
    """forward_ref over the paged cache tree (threads backend): variable-
    length prefill through the block table + per-row-position decode. With
    a kernel backend the decode walks the block table inside the Pallas
    kernel (no gathered KV view)."""
    import jax.numpy as jnp

    from repro.models import lm

    def pre_fn(params, prompts, lens, cache):
        hid, cache, _ = lm.forward_ref(cfg, params, prompts, mode="prefill",
                                       cache=cache, lens=lens,
                                       kernel_backend=kernel_backend)
        last = jnp.take_along_axis(
            hid, jnp.maximum(lens - 1, 0)[:, None, None], axis=1)
        return lm.logits_ref(cfg, params, last), cache

    def dec_fn(params, tokens, cache, pos):
        hid, cache, _ = lm.forward_ref(cfg, params, tokens, mode="decode",
                                       cache=cache, pos=pos,
                                       kernel_backend=kernel_backend)
        return lm.logits_ref(cfg, params, hid), cache

    return pre_fn, dec_fn


def _pick(logits, temperature, key):
    """Next-token choice over [B, vocab] logits: greedy argmax at
    temperature 0, else categorical sampling."""
    import jax.numpy as jnp

    if temperature == 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1
    ).astype(jnp.int32)
