"""Reports returned by the Engine.

TrainReport: one report type for the threaded WSP fleet, the BSP all-reduce
loop and the jitted SPMD path. ServeReport: its serving sibling, assembled
by Engine.generate() and the repro.api.serving scheduler. Downstream
analysis (benchmarks, examples, CI asserts) never cares which backend
produced either.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np


@dataclass
class Telemetry:
    """Aggregate observability state (repro.obs MetricsRegistry snapshot)
    attached to TrainReport / ServeReport when the Engine runs with an
    enabled tracer. The same payload is embedded in exported traces under
    the top-level 'telemetry' key, so bench/CI code reads one schema."""

    counters: dict = field(default_factory=dict)
    gauges: dict = field(default_factory=dict)
    histograms: dict = field(default_factory=dict)

    @classmethod
    def from_metrics(cls, registry) -> "Telemetry":
        snap = registry.snapshot()
        return cls(counters=snap["counters"], gauges=snap["gauges"],
                   histograms=snap["histograms"])

    def to_dict(self) -> dict:
        return {"counters": dict(self.counters), "gauges": dict(self.gauges),
                "histograms": dict(self.histograms)}

    def hist_quantile(self, name: str, q: float) -> Optional[float]:
        from repro.obs.metrics import quantile_from_snapshot
        return quantile_from_snapshot(self.histograms.get(name), q)

    def staleness_max(self) -> Optional[float]:
        h = self.histograms.get("wsp/staleness")
        return h["max"] if h and h["count"] else None

    def bubble_fraction(self) -> Optional[float]:
        b = self.counters.get("pipe/bubble_s", 0.0)
        c = self.counters.get("pipe/busy_s", 0.0)
        return b / (b + c) if (b + c) > 0 else None

    def link_utilization(self, wall_s: float) -> dict:
        """link name -> modeled busy fraction of the run's wall clock."""
        out = {}
        for k, v in self.gauges.items():
            if k.startswith("link/") and k.endswith("/modeled_s"):
                name = k.split("/", 2)[1]
                out[name] = min(1.0, v / wall_s) if wall_s > 0 else 0.0
        return out


@dataclass
class TrainReport:
    losses: list = field(default_factory=list)      # (wall_s, wid, loss)
    waves: int = 0
    wall_s: float = 0.0
    # wid -> seconds that worker spent blocked at its sync gate. Populated
    # by every backend: threads (WSP clock waits), bsp (per-wave straggler
    # wait = slowest VW's wave time minus own), spmd ({"spmd": 0.0} — the
    # jitted step has no host-visible gate)
    wait_seconds: dict = field(default_factory=dict)
    telemetry: Optional[Telemetry] = None           # when tracing is enabled
    bytes_pushed: int = 0
    bytes_wire: int = 0
    comm_seconds: float = 0.0                       # modeled network time
    overlap_seconds: float = 0.0                    # comm hidden under compute
    push_wait_seconds: float = 0.0                  # comm NOT hidden (blocked)
    comm: dict = field(default_factory=dict)        # transport link stats
    # fault / recovery accounting (repro.faults; zeros on fault-free runs)
    waves_requested: int = 0    # max_waves * initial fleet size
    gate_timeouts: int = 0      # staleness gates that timed out (loud, not
                                # silent: fit() raises DegradedRunError
                                # unless FaultPolicy.allow_degraded)
    crashes: int = 0            # workers that died (injected or fail_at)
    late_pushes: int = 0        # pushes applied after the pusher left the
                                # clock (delta kept, clock untouched)
    ps_stalls: int = 0          # injected parameter-server apply stalls
    drops: int = 0              # transport attempts dropped
    retries: int = 0            # transport retries issued
    evictions: list = field(default_factory=list)   # (wid, at_clock,
                                                    #  reason, rejoined)
    rejoins: list = field(default_factory=list)     # successor wids

    def fault_digest(self) -> dict:
        """The run's canonical fault/recovery record, restricted to fields
        that are a deterministic function of the Plan: every entry is
        anchored to logical indices (wave numbers, per-path attempt
        counters), never to host timing. Two runs of the same seeded
        scenario must produce equal digests — the chaos suite's
        determinism assertion. Timing-sensitive observations (total waves
        including a rejoiner's, late_pushes, eviction clocks) stay on the
        report but out of the digest."""
        return {
            "waves_requested": self.waves_requested,
            "gate_timeouts": self.gate_timeouts,
            "crashes": self.crashes,
            "drops": self.drops,
            "retries": self.retries,
            "drops_by_link": dict(self.comm.get("drops_by_link", {})),
            "retries_by_link": dict(self.comm.get("retries_by_link", {})),
            "evictions": sorted((w, r) for w, _, r, _ in self.evictions),
            "rejoins": sorted(self.rejoins),
        }

    def loss_curve(self):
        """(wall_s, loss) arrays in wall-clock order. Sorts by the timestamp
        only: full-tuple sorting would fall through to comparing worker ids
        on wall-clock ties, mis-ordering (or raising, for mixed-type ids)."""
        pts = sorted(self.losses, key=lambda p: p[0])
        return (np.array([p[0] for p in pts]),
                np.array([p[2] for p in pts]))

    def losses_by_worker(self) -> dict:
        """wid -> loss sequence in push order (deterministic per worker even
        when wall-clock interleaving across workers is not)."""
        out: dict = {}
        for _, wid, loss in self.losses:
            out.setdefault(wid, []).append(loss)
        return out


@dataclass
class RequestStats:
    """Per-request accounting from the serving scheduler."""

    rid: int
    prompt_len: int = 0
    tokens: list = field(default_factory=list)    # generated token ids
    admitted_step: int = -1     # global decode step at admission
    finished_step: int = -1     # global decode step at retirement
    slot: int = -1              # batch slot the request occupied
    group: int = -1             # admission group: index of the batched
                                # prefill call this request rode in
    prefill_s: float = 0.0      # duration of that batched prefill call —
                                # shared by every request of its admission
                                # group, so summing it across requests
                                # over-counts wall time; group-level cost
                                # lives in ServeReport.prefill_s /
                                # prefill_calls, per-request arrival-to-
                                # first-token in ttft_s
    ttft_s: float = 0.0         # arrival -> first token (end of this
                                # request's prefill group), wall clock
    latency_s: float = 0.0      # admission -> last token (wall clock)
    retries: int = 0            # slot-fault recoveries this request took
    shed: bool = False          # refused admission under fault pressure
    failed: bool = False        # retired without completing (retry budget
                                # exhausted)

    @property
    def new_tokens(self) -> int:
        return len(self.tokens)


@dataclass
class ServeReport:
    """Serving metrics: the TrainReport sibling for prefill/decode runs."""

    arch: str = ""
    backend: str = ""
    tokens: Any = None          # generate(): [B, gen] generated ids (token
                                # archs) — scheduler runs use `requests`
    requests: list = field(default_factory=list)  # RequestStats
    prefill_s: float = 0.0      # total time inside prefill calls
    prefill_calls: int = 0      # batched prefill calls issued (admission
                                # groups); prefill_s / prefill_calls is the
                                # mean group cost — per-request prefill_s
                                # repeats its group's cost, don't sum it
    decode_s: float = 0.0       # total time inside decode calls
    decode_steps: int = 0       # batched decode calls issued
    slot_steps: int = 0         # sum over decode steps of active slots
    max_batch: int = 0
    wall_s: float = 0.0
    # paged-cache accounting (Scheduler runs; zeros for aligned generate())
    page_size: int = 0          # tokens per KV page
    pages_total: int = 0        # physical pages in the pool
    peak_pages: int = 0         # high-water mark of pages in use
    page_steps: int = 0         # sum over decode steps of pages in use
    admit_blocked: int = 0      # admission rounds refused: pool exhausted
    # memory-manager accounting (repro.serve.memory; zeros when
    # share_prefix/evict/preempt are off or the family has no KV pool)
    prefix_hit_tokens: int = 0  # prompt tokens served from indexed pages
    pages_shared: int = 0       # prefix pages mapped by refcount (no copy)
    cow_copies: int = 0         # copy-on-write page duplications taken
    evictions: int = 0          # cold indexed pages reclaimed (LRU)
    readmit_recomputes: int = 0  # admissions that re-prefilled an evicted
    #                              prefix (recompute-on-readmit)
    preemptions: int = 0        # in-flight requests preempted + replayed
    # fault / recovery accounting (repro.faults; zeros on fault-free runs)
    slot_faults: int = 0        # injected slot faults taken
    requeues: int = 0           # requests re-admitted after a slot fault
    reprefills: int = 0         # slots rebuilt in place from their pages
    quarantined: int = 0        # slots removed from the free pool
    shed: int = 0               # requests refused under fault pressure
    failed_requests: int = 0    # retired incomplete (retry budget spent)
    aborted_step: int = -1      # serving stopped early at this decode step
    #                             (StopServing — e.g. a replica died); -1 =
    #                             ran to completion
    telemetry: Optional[Telemetry] = None  # when tracing is enabled
    # cluster serving (repro.serve.router): merge() fills these on the
    # Router's merged report; empty on single-replica runs
    replicas: list = field(default_factory=list)  # per-replica sub-reports
    #                             (one per replica *run* — a survivor that
    #                             absorbed a re-dispatch round contributes
    #                             one sub-report per round)
    router: dict = field(default_factory=dict)    # Router counters:
    #                             dispatches per policy, affinity_hits,
    #                             rebalances, queue_depth_peak, rounds,
    #                             replica_downs

    @property
    def tokens_out(self) -> int:
        if self.requests:
            return sum(r.new_tokens for r in self.requests)
        if self.tokens is not None:
            return int(np.asarray(self.tokens).size)
        return 0

    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    def ms_per_token(self) -> float:
        return (self.decode_s / self.decode_steps * 1e3
                if self.decode_steps else 0.0)

    def occupancy(self) -> Optional[float]:
        """Mean fraction of decode-batch slots doing useful work (scheduler
        runs only; None for aligned-batch generate()). On a merged report
        each replica's slot-steps are weighed against *its own* capacity
        (decode_steps_i * max_batch_i): the merged max_batch is the fleet's
        total slots, but replicas step independently, so the naive
        slot_steps / (decode_steps * max_batch) would divide every
        replica's work by every other replica's steps."""
        if self.replicas:
            cap = sum(r.decode_steps * r.max_batch for r in self.replicas)
            if not cap:
                return None
            return sum(r.slot_steps for r in self.replicas) / cap
        if not self.decode_steps or not self.max_batch or not self.requests:
            return None
        return self.slot_steps / (self.decode_steps * self.max_batch)

    def mean_ttft(self) -> Optional[float]:
        """Mean arrival-to-first-token over requests (scheduler runs). Each
        request's ttft_s ends at its *own* admission group's prefill, so a
        group's cost enters each member's TTFT once and is never summed
        across the group the way per-request prefill_s would be."""
        if not self.requests:
            return None
        return float(np.mean([r.ttft_s for r in self.requests]))

    def page_utilization(self) -> Optional[float]:
        """Peak *distinct* pages in use as a fraction of the pool
        (scheduler runs only; None for aligned-batch generate()).
        Distinct is load-bearing under prefix sharing: a page mapped
        into N block tables is one page of HBM — summing per-slot
        block-table lengths would double-count exactly the pages
        sharing saves, and the pool-sizing question this answers is the
        peak physical footprint, not a time-averaged occupancy.

        On a merged report the fraction is pool-weighted — each pool-
        bearing replica's peak over the fleet's summed pools (pool-less
        families contribute nothing to either side), which degenerates
        to the plain ratio for a single replica."""
        if self.replicas:
            tot = sum(r.pages_total for r in self.replicas
                      if r.pages_total and r.decode_steps)
            if not tot:
                return None
            return sum(r.peak_pages for r in self.replicas
                       if r.pages_total and r.decode_steps) / tot
        if not self.decode_steps or not self.pages_total:
            return None
        return self.peak_pages / self.pages_total

    @classmethod
    def merge(cls, reports, *, router: Optional[dict] = None,
              wall_s: Optional[float] = None) -> "ServeReport":
        """Fold per-replica sub-reports into one fleet-level ServeReport.

        Additive counters (tokens, prefill/decode time and calls,
        slot-steps, page and memory/fault accounting) sum; `max_batch` and
        `pages_total` sum into the fleet's total capacity; `requests`
        concatenates sorted by rid (the Router never splits or duplicates
        a request, so rids stay unique). `wall_s` defaults to the max over
        sub-reports — replicas run concurrently, so summing their walls
        would undercount throughput by the overlap — and the Router
        passes its own measured wall instead. decode_s/prefill_s DO sum:
        they are cumulative compute-seconds across the fleet, and may
        legitimately exceed wall_s. `occupancy()` and
        `page_utilization()` are replica-weighted (see their docstrings);
        both degenerate to the plain single-replica values for a one-
        element merge."""
        reports = list(reports)
        if not reports:
            raise ValueError("merge() needs at least one sub-report")
        out = cls(arch=reports[0].arch, backend=reports[0].backend,
                  replicas=reports, router=dict(router or {}))
        for r in reports:
            out.requests.extend(r.requests)
            out.prefill_s += r.prefill_s
            out.prefill_calls += r.prefill_calls
            out.decode_s += r.decode_s
            out.decode_steps += r.decode_steps
            out.slot_steps += r.slot_steps
            out.max_batch += r.max_batch
            out.pages_total += r.pages_total
            out.peak_pages += r.peak_pages
            out.page_steps += r.page_steps
            out.admit_blocked += r.admit_blocked
            out.prefix_hit_tokens += r.prefix_hit_tokens
            out.pages_shared += r.pages_shared
            out.cow_copies += r.cow_copies
            out.evictions += r.evictions
            out.readmit_recomputes += r.readmit_recomputes
            out.preemptions += r.preemptions
            out.slot_faults += r.slot_faults
            out.requeues += r.requeues
            out.reprefills += r.reprefills
            out.quarantined += r.quarantined
            out.shed += r.shed
            out.failed_requests += r.failed_requests
            out.page_size = out.page_size or r.page_size
        out.wall_s = wall_s if wall_s is not None else \
            max(r.wall_s for r in reports)
        out.requests.sort(key=lambda r: r.rid)
        return out
