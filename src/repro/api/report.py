"""Reports returned by the Engine.

TrainReport: one report type for the threaded WSP fleet, the BSP all-reduce
loop and the jitted SPMD path. ServeReport: its serving sibling, assembled
by Engine.generate() and the repro.api.serving scheduler. Downstream
analysis (benchmarks, examples, CI asserts) never cares which backend
produced either.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np


@dataclass
class TrainReport:
    losses: list = field(default_factory=list)      # (wall_s, wid, loss)
    waves: int = 0
    wall_s: float = 0.0
    wait_seconds: dict = field(default_factory=dict)
    bytes_pushed: int = 0
    bytes_wire: int = 0
    comm_seconds: float = 0.0                       # modeled network time
    overlap_seconds: float = 0.0                    # comm hidden under compute
    push_wait_seconds: float = 0.0                  # comm NOT hidden (blocked)
    comm: dict = field(default_factory=dict)        # transport link stats

    def loss_curve(self):
        """(wall_s, loss) arrays in wall-clock order. Sorts by the timestamp
        only: full-tuple sorting would fall through to comparing worker ids
        on wall-clock ties, mis-ordering (or raising, for mixed-type ids)."""
        pts = sorted(self.losses, key=lambda p: p[0])
        return (np.array([p[0] for p in pts]),
                np.array([p[2] for p in pts]))

    def losses_by_worker(self) -> dict:
        """wid -> loss sequence in push order (deterministic per worker even
        when wall-clock interleaving across workers is not)."""
        out: dict = {}
        for _, wid, loss in self.losses:
            out.setdefault(wid, []).append(loss)
        return out


@dataclass
class RequestStats:
    """Per-request accounting from the serving scheduler."""

    rid: int
    prompt_len: int = 0
    tokens: list = field(default_factory=list)    # generated token ids
    admitted_step: int = -1     # global decode step at admission
    finished_step: int = -1     # global decode step at retirement
    slot: int = -1              # batch slot the request occupied
    prefill_s: float = 0.0      # duration of the batched prefill call this
                                # request rode in (shared by every request
                                # of its admission group, so summing it
                                # across requests over-counts wall time)
    latency_s: float = 0.0      # admission -> last token (wall clock)

    @property
    def new_tokens(self) -> int:
        return len(self.tokens)


@dataclass
class ServeReport:
    """Serving metrics: the TrainReport sibling for prefill/decode runs."""

    arch: str = ""
    backend: str = ""
    tokens: Any = None          # generate(): [B, gen] generated ids (token
                                # archs) — scheduler runs use `requests`
    requests: list = field(default_factory=list)  # RequestStats
    prefill_s: float = 0.0      # total time inside prefill calls
    decode_s: float = 0.0       # total time inside decode calls
    decode_steps: int = 0       # batched decode calls issued
    slot_steps: int = 0         # sum over decode steps of active slots
    max_batch: int = 0
    wall_s: float = 0.0
    # paged-cache accounting (Scheduler runs; zeros for aligned generate())
    page_size: int = 0          # tokens per KV page
    pages_total: int = 0        # physical pages in the pool
    peak_pages: int = 0         # high-water mark of pages in use
    page_steps: int = 0         # sum over decode steps of pages in use
    admit_blocked: int = 0      # admission rounds refused: pool exhausted

    @property
    def tokens_out(self) -> int:
        if self.requests:
            return sum(r.new_tokens for r in self.requests)
        if self.tokens is not None:
            return int(np.asarray(self.tokens).size)
        return 0

    def tokens_per_s(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    def ms_per_token(self) -> float:
        return (self.decode_s / self.decode_steps * 1e3
                if self.decode_steps else 0.0)

    def occupancy(self) -> Optional[float]:
        """Mean fraction of decode-batch slots doing useful work (scheduler
        runs only; None for aligned-batch generate())."""
        if not self.decode_steps or not self.max_batch or not self.requests:
            return None
        return self.slot_steps / (self.decode_steps * self.max_batch)

    def page_utilization(self) -> Optional[float]:
        """Mean fraction of the KV page pool in use across decode steps
        (scheduler runs only; None for aligned-batch generate())."""
        if not self.decode_steps or not self.pages_total:
            return None
        return self.page_steps / (self.decode_steps * self.pages_total)
