"""Training report returned by every Engine backend.

One report type for the threaded WSP fleet, the BSP all-reduce loop and the
jitted SPMD path, so downstream analysis (benchmarks, examples, CI asserts)
never cares which backend produced it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class TrainReport:
    losses: list = field(default_factory=list)      # (wall_s, wid, loss)
    waves: int = 0
    wall_s: float = 0.0
    wait_seconds: dict = field(default_factory=dict)
    bytes_pushed: int = 0
    bytes_wire: int = 0
    comm_seconds: float = 0.0                       # modeled network time
    overlap_seconds: float = 0.0                    # comm hidden under compute
    push_wait_seconds: float = 0.0                  # comm NOT hidden (blocked)
    comm: dict = field(default_factory=dict)        # transport link stats

    def loss_curve(self):
        """(wall_s, loss) arrays in wall-clock order. Sorts by the timestamp
        only: full-tuple sorting would fall through to comparing worker ids
        on wall-clock ties, mis-ordering (or raising, for mixed-type ids)."""
        pts = sorted(self.losses, key=lambda p: p[0])
        return (np.array([p[0] for p in pts]),
                np.array([p[2] for p in pts]))

    def losses_by_worker(self) -> dict:
        """wid -> loss sequence in push order (deterministic per worker even
        when wall-clock interleaving across workers is not)."""
        out: dict = {}
        for _, wid, loss in self.losses:
            out.setdefault(wid, []).append(loss)
        return out
