"""Synchronization policies — the strategy hierarchy behind Engine.fit().

The paper's three synchronization models are one axis of a Plan:

  WSP(D, pull_every, async_push)  wave synchronous parallel: threaded VWs
                                  against the sharded parameter server with
                                  the global staleness bound D (Sections 4-5)
  BSP()                           the AllReduce baseline ("Horovod" analogue):
                                  every wave all deltas are ring-all-reduced
                                  and applied to one global copy
  ASP(...)                        asynchronous parallel = WSP with an
                                  unbounded clock distance (D = "infinity")

A policy is pure declarative configuration plus a single `execute(engine)`
dispatch; the execution loops live in `repro.api.engine` so all policies
share loaders, timing and report assembly.
"""
from __future__ import annotations

from dataclasses import dataclass

# "D = infinity" as an int the WSP clock machine can compare against; any
# realistic wave count is orders of magnitude below it.
UNBOUNDED_D = 1 << 30


@dataclass(frozen=True)
class SyncPolicy:
    """Base class: every policy validates itself and knows how to run."""

    @property
    def kind(self) -> str:
        return type(self).__name__.lower()

    def validate(self) -> None:
        pass

    def execute(self, engine, **kw):
        raise NotImplementedError

    def describe(self) -> str:
        return self.kind


@dataclass(frozen=True)
class WSP(SyncPolicy):
    D: int = 0                  # global clock-distance bound (0 = lock step)
    pull_every: int = 1         # pull w_global every k waves
    async_push: bool = False    # overlap the wave push with the next compute

    def validate(self) -> None:
        if not isinstance(self.D, int) or self.D < 0:
            raise ValueError(f"WSP staleness bound D must be an int >= 0, "
                             f"got {self.D!r}")
        if self.pull_every < 1:
            raise ValueError(f"pull_every must be >= 1, got {self.pull_every}")

    def execute(self, engine, **kw):
        return engine._fit_threaded(self, **kw)

    def describe(self) -> str:
        d = "inf" if self.D >= UNBOUNDED_D else self.D
        return (f"WSP(D={d}, pull_every={self.pull_every}, "
                f"async_push={self.async_push})")


@dataclass(frozen=True)
class ASP(WSP):
    """Fully asynchronous parallel: WSP with the staleness gate disabled."""
    D: int = UNBOUNDED_D


@dataclass(frozen=True)
class BSP(SyncPolicy):
    """Synchronous AllReduce data parallelism (the paper's Horovod baseline).
    Wall clock is simulated straggler-gated time: each wave costs the max
    over VWs of (compute + slowdown) plus the modeled all-reduce."""
    average: bool = True        # mean the deltas (each VW sees 1/N of batch)

    def execute(self, engine, **kw):
        return engine._fit_bsp(self, **kw)
