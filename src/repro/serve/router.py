"""Scale-out serving: heterogeneous DP replicas behind a topology-priced
Router.

HetPipe's thesis — data parallelism over *heterogeneous* virtual workers,
whimpy nodes included, beats homogeneous-only scaling — applied to
inference. A `Router` owns N serve replicas (`partition.data`), each a
full single-replica stack: its own Engine (compiled executors sized to the
replica), its own `CacheStore` page pool, its own `MemoryManager` prefix
index, its own continuous-batching `Scheduler` slot pool. Replicas may be
heterogeneous (`ServeSpec.replicas`): a whimpy replica shrinks
`max_batch`/`max_pages`, and the dispatch scoring naturally steers
short-prompt / short-budget traffic its way — a long request is infeasible
(or expensive) on a small pool, a short one is cheap anywhere, so under
load the big replica keeps the long tail and the whimpies absorb the
short traffic.

Dispatch policies (`ROUTER_POLICIES`):

  least_loaded  requests dispatch in arrival order; each goes to the
                replica minimizing load + net, where load counts the
                queue depth already booked against the replica's slots
                plus its page-pool pressure (pages_in_use + booked pages
                over pages_total), in units of a nominal decode-step cost
  deadline      requests dispatch in slack order (deadline minus tokens
                still needed — the Scheduler's slack ordering, FIFO among
                ties), to the same min-cost replica; each replica's own
                Scheduler also runs its "deadline" admission policy

Both are priced by `dist.topology` alpha-beta link costs: the client sits
at the topology's `ps` endpoint, and a dispatch pays the client->replica
path (`ClusterTopology.path_links`) for the prompt bytes out plus the
generated tokens back. A fast-but-far replica can therefore lose to a
near whimpy one — cost-modeled placement in the spirit of the paper's
profiled-network partitioner.

Session/prefix affinity: requests sharing a page-aligned prompt prefix
(the first `page_size`-token run) stick to one replica, first by probing
each live replica's `PrefixIndex` read-only (`index.match` — the replica
whose pool already holds those pages wins) and then by a sticky
first-dispatch map for prefixes no index holds yet. Shared system prompts
thus hit one replica's refcounted pages (`prefix_hit_tokens` > 0) instead
of being recomputed once per replica.

Bit-identity invariant: routing never changes a request's token stream.
Per-request picks are keyed by (sample_seed, rid, k) and decode rows are
independent of their co-batched neighbors, so any assignment of requests
to replicas — including replay after a replica death — emits exactly the
streams a single-replica Scheduler would (MoE capacity routing excepted,
as everywhere in the serve stack).

Replica death (`repro.faults.ReplicaDown`, threads-only like every fault
seam): the victim's Scheduler aborts via `StopServing` at its own decode
step; retired requests keep their (complete, bit-identical) streams, and
the Router re-dispatches the unfinished remainder onto the survivors in
the next round — requeue semantics, counted as rebalances.

    from repro.api import Engine, get_preset
    from repro.serve.router import Router
    plan = get_preset("serve_cluster")
    report = Router(plan).run(requests)      # merged ServeReport
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.api.engine import Engine
from repro.api.plan import Plan, ReplicaSpec
from repro.api.report import ServeReport, Telemetry
from repro.api.serving import Scheduler, StopServing
from repro.faults.plan import ReplicaDown, SlotFault
from repro.obs import NULL_TRACER
from repro.serve.memory import MemoryManager

ROUTER_POLICIES = ("least_loaded", "deadline")

_INFEASIBLE = float("inf")


class Replica:
    """One serve replica: a single-replica Plan (partition.data=1) run by
    its own Engine/Scheduler over a persistent CacheStore + MemoryManager
    (persistent so the prefix index stays warm across dispatch rounds and
    the Router can probe it read-only for affinity)."""

    def __init__(self, idx: int, plan: Plan, host: str, engine: Engine,
                 policy: str):
        self.idx = idx
        self.plan = plan
        self.host = host
        self.engine = engine
        self.scheduler = Scheduler(engine, policy=policy)
        self.store = engine.serve_store()
        sv = plan.serve
        self.mm = MemoryManager(self.store, share_prefix=sv.share_prefix,
                                evict=sv.evict, preempt=sv.preempt,
                                policy=policy,
                                metrics=engine.tracer.metrics)
        self.max_batch = sv.max_batch
        self.pages_total = self.store.pages_total
        self.down = False

    def pages_for(self, tokens: int) -> int:
        return self.store.layout.pages_for(tokens) if self.store._has_pool \
            else 0

    def prefix_hit(self, prompt) -> int:
        """Read-only affinity probe: prompt tokens this replica's index
        already holds pages for."""
        if not self.mm.share_prefix:
            return 0
        hit, _ = self.mm.index.match(prompt)
        return hit

    def describe(self) -> str:
        return (f"r{self.idx}@{self.host}: batch={self.max_batch} "
                f"pages={self.pages_total}")


class Router:
    """Owns the replica fleet of a data-parallel serve Plan
    (partition.data > 1 on the threads backend) and routes requests.

    The Plan is the cluster-level spec: `ServeSpec.max_batch`/`max_pages`
    are the per-replica ceiling, `ServeSpec.replicas` shrinks individual
    replicas, `cluster.topology` prices dispatch (None = all replicas
    equidistant). Model parameters are materialized once and shared by
    every replica Engine — same arch, same seed, so replicas are exact
    clones of the single-replica model and token streams stay
    bit-identical to a single-replica run.

    `step_cost_s` is the nominal cost of one decode step used to convert
    queue depth and page pressure into seconds, the currency link costs
    are priced in — it sets how much queueing advantage a far replica
    must offer before beating a near one.
    """

    def __init__(self, plan: Plan, *, policy: str = "least_loaded",
                 tracer=None, step_cost_s: float = 2e-3,
                 parallel: Optional[bool] = None):
        if not isinstance(plan, Plan):
            raise TypeError(f"Router wants a Plan, got {type(plan).__name__}")
        if plan.serve is None:
            raise ValueError("the Router drives serve Plans; Plan.serve is "
                             "unset — give the Plan a ServeSpec")
        if plan.run.backend != "threads":
            raise ValueError("data-parallel serve replicas are threads-"
                             "backend only for now; the spmd mesh serves "
                             "as a single replica")
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; expected "
                             f"one of {ROUTER_POLICIES}")
        self.plan = plan
        self.policy = policy
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.step_cost_s = step_cost_s
        if parallel is None:
            # threads only overlap where there are cores to overlap on; a
            # single-core host would just interleave the replicas and
            # contaminate each replica's measured wall with the others'
            # GIL slices (streams are bit-identical either way)
            import os
            parallel = (os.cpu_count() or 1) > 1
        self.parallel = parallel
        sv = plan.serve
        n = max(1, plan.partition.data)
        specs = list(sv.replicas) or [ReplicaSpec()] * n

        topo = plan.cluster.topology
        if isinstance(topo, str):
            from repro.dist.topology import make_topology
            topo = make_topology(topo, n)
        self.topology = topo

        # shared params: every replica is an exact clone of the model the
        # single-replica Engine would build (same arch, same seed)
        import jax
        from repro.models import lm
        params, _ = lm.init_params(plan.arch,
                                   jax.random.PRNGKey(plan.run.seed))

        sched_policy = "deadline" if policy == "deadline" else "fifo"
        self.replicas: list[Replica] = []
        for i, spec in enumerate(specs):
            host = spec.host or f"vw{i}"
            if self.topology is not None:
                self.topology.link("ps", host)   # unknown hosts fail here
            rplan = plan.replace(
                partition__data=1,
                cluster__topology=None,
                serve__max_batch=spec.max_batch or sv.max_batch,
                serve__max_pages=spec.max_pages or sv.max_pages,
                serve__replicas=(),
                faults=self._replica_faults(i, spec.max_batch
                                            or sv.max_batch))
            eng = Engine(rplan, params=params,
                         tracer=self.tracer.scoped(f"r{i}/"))
            self.replicas.append(Replica(i, rplan, host, eng, sched_policy))

        # ReplicaDown events fire at the victim's own decode step
        self._down_at: dict[int, int] = {}
        if plan.faults is not None:
            for ev in plan.faults.of_type(ReplicaDown):
                self._down_at[ev.replica] = ev.step

        self._affinity: dict[tuple, int] = {}    # prefix key -> replica idx
        self._counters = {"dispatches": 0, "affinity_hits": 0,
                          "rebalances": 0, "queue_depth_peak": 0,
                          "rounds": 0, "replica_downs": 0}

    # ------------------------------------------------------------------
    def _replica_faults(self, idx: int, max_batch: int):
        """The per-replica FaultPlan: SlotFaults land on the first replica
        whose decode batch contains the named slot (deterministic — the
        cluster-level slot index has no replica attribution); ReplicaDown
        events are the Router's own and are stripped."""
        faults = self.plan.faults
        if faults is None:
            return None
        slots = faults.of_type(SlotFault)
        mine = []
        for ev in slots:
            owner = next((j for j, s in enumerate(
                list(self.plan.serve.replicas)
                or [ReplicaSpec()] * max(1, self.plan.partition.data))
                if ev.slot < (s.max_batch
                              or self.plan.serve.max_batch)), None)
            if owner == idx:
                mine.append(ev)
        if not mine:
            return None
        from repro.faults.plan import FaultPlan
        return FaultPlan(seed=faults.seed, events=tuple(mine))

    # ------------------------------------------------------------------
    # dispatch pricing
    # ------------------------------------------------------------------
    def _net_cost(self, host: str, nbytes: float) -> float:
        """Client->replica alpha-beta cost: the client sits at the
        topology's 'ps' endpoint; a replica on the ps host is free."""
        topo = self.topology
        if topo is None or host == topo.ps_host:
            return 0.0
        return sum(l.transfer_time(nbytes)
                   for l in topo.path_links(("ps", host)))

    def _limit(self, r) -> int:
        return r.max_new_tokens or self.plan.serve.gen

    def _score(self, rep: Replica, booked_depth: int, booked_pages: int,
               prompt_len: int, limit: int) -> float:
        """Dispatch cost (seconds) of sending this request to `rep`:
        queue + page pressure in decode-step currency, plus the priced
        client->replica round trip. inf = infeasible (the request could
        never be admitted there)."""
        need_pages = rep.pages_for(prompt_len + limit)
        if rep.pages_total and need_pages > rep.pages_total:
            return _INFEASIBLE
        load = (booked_depth / rep.max_batch) * self.step_cost_s
        if rep.pages_total:
            frac = (rep.store.pages_in_use + booked_pages + need_pages) \
                / rep.pages_total
            load += frac * self.step_cost_s
        net = self._net_cost(rep.host, 4.0 * prompt_len) \
            + self._net_cost(rep.host, 4.0 * limit)
        return load + net

    def _prefix_key(self, prompt) -> Optional[tuple]:
        """Affinity key: the first page-aligned token run of the prompt
        (first-page granularity — requests sharing at least one full page
        of system prompt share the key). None when the prompt is shorter
        than a page or the family has no pool to share."""
        rep0 = self.replicas[0]
        ps = rep0.store.layout.page_size
        # mm.share_prefix is already gated on the family having a pool at
        # all (RWKV stores have no pages to share)
        if not rep0.mm.share_prefix or ps <= 0 or len(prompt) < ps:
            return None
        return tuple(int(t) for t in prompt[:ps])

    # ------------------------------------------------------------------
    def _dispatch(self, pending) -> dict[int, list]:
        """Assign every pending request to a live replica. Returns
        {replica idx: [Request, ...]} preserving arrival order within
        each replica (the Scheduler re-applies its own admission policy
        inside)."""
        tr, sv = self.tracer, self.plan.serve
        live = [rep for rep in self.replicas if not rep.down]
        if not live:
            raise RuntimeError("every serve replica is down; nothing can "
                               "dispatch")
        if self.policy == "deadline":
            def slack(r):
                return (r.deadline - self._limit(r)) if r.deadline \
                    else float("inf")
            order = sorted(range(len(pending)),
                           key=lambda i: slack(pending[i]))
        else:
            order = list(range(len(pending)))
        booked_depth = {rep.idx: 0 for rep in live}
        booked_pages = {rep.idx: 0 for rep in live}
        assign: dict[int, list] = {rep.idx: [] for rep in live}
        for qi in order:
            r = pending[qi]
            prompt = np.asarray(r.prompt)
            plen = int(prompt.shape[0])
            limit = self._limit(r)

            def feasible(rep):
                return self._score(rep, booked_depth[rep.idx],
                                   booked_pages[rep.idx], plen,
                                   limit) < _INFEASIBLE

            chosen, via = None, "score"
            key = self._prefix_key(prompt)
            if key is not None:
                # live probe first: the replica whose PrefixIndex already
                # holds this prefix's pages wins (read-only match)
                hits = [(rep.prefix_hit(prompt), rep.idx) for rep in live]
                best_hit, best_idx = max(hits)
                if best_hit > 0 and feasible(self.replicas[best_idx]):
                    chosen, via = best_idx, "probe"
                elif key in self._affinity:
                    sticky = self._affinity[key]
                    rep = self.replicas[sticky]
                    if not rep.down and feasible(rep):
                        chosen, via = sticky, "sticky"
                    else:
                        # the affinity target is gone/full: rebalance
                        self._counters["rebalances"] += 1
                        tr.metrics.counter_inc("serve/router_rebalances")
            if chosen is None:
                scored = [(self._score(rep, booked_depth[rep.idx],
                                       booked_pages[rep.idx], plen, limit),
                           rep.idx) for rep in live]
                score, chosen = min(scored)
                if score == _INFEASIBLE:
                    raise ValueError(
                        f"request {r.rid} (prompt {plen} + gen {limit} "
                        f"tokens) fits no live replica's page pool; "
                        f"shrink the request or grow a replica "
                        f"({', '.join(rep.describe() for rep in live)})")
            rep = self.replicas[chosen]
            assign[chosen].append(r)
            booked_depth[chosen] += 1
            booked_pages[chosen] += rep.pages_for(plen + limit)
            if key is not None:
                self._affinity.setdefault(key, chosen)
            self._counters["dispatches"] += 1
            tr.metrics.counter_inc("serve/router_dispatches")
            if via != "score":
                self._counters["affinity_hits"] += 1
                tr.metrics.counter_inc("serve/router_affinity_hits")
                tr.instant("router", "affinity_hit", rid=r.rid,
                           replica=chosen, via=via)
            tr.instant("router", "dispatch", rid=r.rid, replica=chosen,
                       policy=self.policy, queue_depth=booked_depth[chosen])
        return assign

    # ------------------------------------------------------------------
    def _run_replica(self, rep: Replica, reqs: list) -> ServeReport:
        cb = None
        down_step = self._down_at.get(rep.idx)
        if down_step is not None:
            def cb(step, active, _t=down_step):
                if step >= _t:
                    raise StopServing()
        return rep.scheduler.run(reqs, callback=cb, store=rep.store,
                                 mm=rep.mm)

    def run(self, requests) -> ServeReport:
        """Route `requests` over the replica fleet to completion and
        return the merged ServeReport (per-replica sub-reports under
        `.replicas`, router counters under `.router`)."""
        tr = self.tracer
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("request rids must be unique: the Router "
                             "tracks completion and replay by rid")
        t0 = time.monotonic()
        pending = list(requests)
        sub_reports: list[ServeReport] = []
        self._busy: dict[int, float] = {}
        while pending:
            depth = len(pending)
            self._counters["queue_depth_peak"] = max(
                self._counters["queue_depth_peak"], depth)
            tr.counter("router", "queue_depth", depth)
            if self._counters["rounds"]:
                # everything here survived a replica death: requeued
                self._counters["rebalances"] += depth
                tr.metrics.counter_inc("serve/router_rebalances", depth)
            assign = self._dispatch(pending)
            active = {i: reqs for i, reqs in assign.items() if reqs}
            with tr.span("router", "round",
                         round=self._counters["rounds"],
                         requests=depth, replicas=len(active)):
                if self.parallel and len(active) > 1:
                    with ThreadPoolExecutor(max_workers=len(active)) as ex:
                        futs = {i: ex.submit(self._run_replica,
                                             self.replicas[i], reqs)
                                for i, reqs in active.items()}
                        results = {i: f.result() for i, f in futs.items()}
                else:
                    results = {i: self._run_replica(self.replicas[i], reqs)
                               for i, reqs in active.items()}
            done = set()
            for i, rep_report in sorted(results.items()):
                rep_report.router = {"replica": i}
                self._busy[i] = self._busy.get(i, 0.0) + rep_report.wall_s
                sub_reports.append(rep_report)
                done |= {s.rid for s in rep_report.requests}
                if rep_report.aborted_step >= 0:
                    self.replicas[i].down = True
                    self._down_at.pop(i, None)
                    self._counters["replica_downs"] += 1
                    tr.instant("router", "replica_down", replica=i,
                               step=rep_report.aborted_step)
                    tr.metrics.counter_inc("fault/replica_downs")
            survivors = [r for r in pending if r.rid not in done]
            if len(survivors) == len(pending):
                raise RuntimeError(
                    f"dispatch round {self._counters['rounds']} completed "
                    f"no requests; refusing to spin "
                    f"({len(pending)} pending)")
            pending = survivors
            self._counters["rounds"] += 1
        wall = time.monotonic() - t0
        tr.metrics.gauge_set("serve/router_queue_depth",
                             self._counters["queue_depth_peak"])
        router = dict(self._counters)
        router["policy"] = self.policy
        router["replicas"] = len(self.replicas)
        router["dispatches_by_policy"] = {
            self.policy: self._counters["dispatches"]}
        # modeled fleet wall: each replica rides its own node in the
        # deployment the Plan describes, so fleet latency is the busiest
        # replica's wall, not the sum a single shared host serializes
        # (wall_s above stays the honest measured host wall)
        router["modeled_fleet_wall_s"] = max(self._busy.values(),
                                             default=wall)
        merged = ServeReport.merge(sub_reports, router=router, wall_s=wall)
        if tr.enabled:
            merged.telemetry = Telemetry.from_metrics(tr.metrics)
        return merged


def route(plan: Plan, requests, **kw) -> ServeReport:
    """One-shot convenience: Router(plan, **kw).run(requests)."""
    return Router(plan, **kw).run(requests)
