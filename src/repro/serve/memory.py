"""Serve-side memory policy: prefix sharing, page eviction, preemption.

The policy layer above `repro.serve.cache`. A `CacheStore` rations a fixed
page pool mechanically — refcounts, free list, block tables — but leaves
three decisions open that turn the pool into throughput when millions of
requests share the same system prompt (the ROADMAP's production memory
manager):

  sharing     a **prefix index** — a trie at page granularity keyed by
              page-sized token runs — maps a request's longest cached
              prefix onto existing pages. Full pages are shared in place
              (refcount bumped, zero copies); a trailing *partial* page is
              shared only when the whole prompt matched through it, and
              then by **copy-on-write**: the admitting slot gets a device
              copy it may write generated tokens into, while the indexed
              original stays immutable for the next sharer. Prefill skips
              writing matched pages (the Scheduler passes skip_pages to
              `Engine.prefill_into`) — shared prefixes cost pages once,
              not once per request.

  eviction    retired requests leave their indexed prompt pages *cold*:
              resident and matchable, refcount zero. Under pool pressure
              `make_room` releases cold pages leaf-first in LRU order of
              their `last_touch` decode-step stamp. A prompt readmitted
              after its pages were evicted simply recomputes its prefill
              (recompute-on-readmit, counted in `readmit_recomputes`) —
              eviction can cost latency, never correctness.

  preemption  when even eviction cannot make room, `victim` picks an
              in-flight request to kick: fewest generated tokens (the
              cheapest replay) under FIFO, most deadline slack under the
              "deadline" policy. The Scheduler releases its pages and
              requeues it at the front; token picks are keyed by
              (sample_seed, rid, k), so the replayed stream is
              bit-identical to the uninterrupted one.

Families without a full-attention KV pool (all-windowed, RWKV/SSD-only
state is fixed-size per slot) have nothing to share, evict or preempt
for: the manager is **inert** there — matches always miss, every counter
stays 0, and admission gating degenerates to the store's always-true
`can_alloc`.

Bit-identity invariant: a shared page holds exactly the K/V the sharer's
own prefill would have computed (same tokens, same positions, same
params), writes into shared or retained pages are forbidden (CoW first),
and replay regenerates token streams from the prompt under per-rid
sampling keys — so `share_prefix`/`evict`/`preempt` never change a single
emitted token, only the page accounting underneath.
"""
from __future__ import annotations

from typing import Optional

from repro.serve.cache import CacheStore


class _Node:
    """One indexed page: the page holding the `ntok` prompt positions
    that extend the chain reaching it from the root. Full pages
    (ntok == page_size) chain on through `children`; partial pages are
    leaves by construction — a prefix can only continue from a page
    boundary."""

    __slots__ = ("tokens", "page", "ntok", "parent", "children", "partial",
                 "last_touch")

    def __init__(self, tokens, page, parent):
        self.tokens = tokens        # this page's token run (len == ntok)
        self.page = page
        self.ntok = len(tokens)
        self.parent = parent
        self.children: dict = {}    # full-page runs -> _Node
        self.partial: dict = {}     # shorter trailing runs -> _Node
        self.last_touch = 0


class PrefixIndex:
    """Token trie at page granularity over a CacheStore's KV pool.

    Each node owns one physical page and the exact token run it holds;
    a path from the root spells a prompt prefix and the page chain that
    caches it. The index retains its pages in the store (cold at
    refcount zero), and eviction removes leaf nodes first so an indexed
    chain never dangles."""

    def __init__(self, page_size: int):
        self.ps = page_size
        self.root = _Node((), -1, None)
        self.by_page: dict[int, _Node] = {}

    def __len__(self) -> int:
        return len(self.by_page)

    # ---- lookup ------------------------------------------------------
    def match(self, prompt):
        """Longest indexed prefix of `prompt`: (hit_tokens, [pages]).

        Walks full-page children while whole pages keep matching; at the
        frontier, a partial leaf extends the hit only when the *entire
        remaining prompt* equals its run — a partial page is shared by
        copy-on-write, which only pays off when the prompt ends inside
        it (otherwise prefill must rewrite the page anyway)."""
        toks = tuple(int(t) for t in prompt)
        node, hit, pages = self.root, 0, []
        while len(toks) - hit >= self.ps:
            nxt = node.children.get(toks[hit:hit + self.ps])
            if nxt is None:
                break
            node = nxt
            hit += self.ps
            pages.append(nxt.page)
        rest = toks[hit:]
        if rest:
            part = node.partial.get(rest)
            if part is not None:
                hit += part.ntok
                pages.append(part.page)
        return hit, pages

    # ---- insertion ---------------------------------------------------
    def insert(self, store: CacheStore, prompt, pages, step: int) -> None:
        """Index `prompt`'s page chain (the slot's leading pages, in
        order). Idempotent: runs already indexed keep their original
        page — a sharer's CoW copy of a partial page is never indexed
        over the original. New pages get a store retain() hold."""
        toks = tuple(int(t) for t in prompt)
        node, pos, i = self.root, 0, 0
        while pos < len(toks):
            n = min(self.ps, len(toks) - pos)
            run = toks[pos:pos + n]
            table = node.children if n == self.ps else node.partial
            nxt = table.get(run)
            if nxt is None:
                nxt = _Node(run, pages[i], node)
                table[run] = nxt
                self.by_page[pages[i]] = nxt
                store.retain(pages[i])
            nxt.last_touch = step
            store.last_touch[nxt.page] = step
            node, pos, i = nxt, pos + n, i + 1
            if n < self.ps:
                break

    # ---- eviction ----------------------------------------------------
    def evict_lru(self, store: CacheStore, need_free: int,
                  evicted_keys: Optional[set] = None,
                  protect=()) -> int:
        """Release index holds leaf-first, coldest `last_touch` first,
        until the store has `need_free` free pages (or no evictable node
        remains). Only nodes no slot maps (refcount zero) are
        candidates; a mapped page implies every ancestor is mapped by
        the same slot, so leaf-first order is also dependency order.
        `protect` pins pages the in-flight admission just matched.
        Evicted prefixes are recorded in `evicted_keys` so readmissions
        can be attributed to recompute-on-readmit."""
        protect = set(protect)
        evicted = 0
        while len(store._free) < need_free:
            best = None
            stack = [self.root]
            while stack:
                n = stack.pop()
                stack.extend(n.children.values())
                stack.extend(n.partial.values())
                if n is self.root or n.children or n.partial:
                    continue
                if store._ref[n.page] != 0 or n.page in protect:
                    continue
                if best is None or n.last_touch < best.last_touch:
                    best = n
            if best is None:
                break
            if evicted_keys is not None:
                evicted_keys.add(self._prefix(best))
            self._remove(best)
            store.release(best.page)
            evicted += 1
        return evicted

    def _prefix(self, node: _Node) -> tuple:
        runs = []
        while node is not None and node.parent is not None:
            runs.append(node.tokens)
            node = node.parent
        return sum(reversed(runs), ())

    def _remove(self, node: _Node) -> None:
        table = node.parent.children if node.ntok == self.ps \
            else node.parent.partial
        del table[node.tokens]
        self.by_page.pop(node.page, None)


class MemoryManager:
    """Admission-time memory policy for the Scheduler: quotes page needs
    against the prefix index, evicts cold pages to make room, maps shared
    prefixes (with CoW) at admit, and nominates preemption victims.

    Knobs mirror `ServeSpec`: `share_prefix` turns the index on, `evict`
    lets `make_room` reclaim cold indexed pages, `preempt` lets `victim`
    nominate an in-flight request under pressure. All three are inert on
    pool-less stores. Counters accumulate here and are copied onto the
    `ServeReport` by the Scheduler."""

    def __init__(self, store: CacheStore, *, share_prefix: bool = False,
                 evict: bool = False, preempt: bool = False,
                 policy: str = "fifo", metrics=None):
        self.store = store
        self.share_prefix = share_prefix and store._has_pool
        self.evict = evict and store._has_pool
        self.preempt = preempt and store._has_pool
        self.policy = policy
        self.metrics = metrics
        self.index = PrefixIndex(store.layout.page_size)
        self.evicted_prefixes: set[tuple] = set()
        self.prefix_hit_tokens = 0
        self.prompt_tokens = 0
        self.pages_shared = 0
        self.evictions = 0
        self.readmit_recomputes = 0

    # ---- admission ---------------------------------------------------
    def plan_admit(self, prompt, need_tokens: int):
        """Quote an admission: (hit_tokens, matched_pages, need_fresh).

        A fully-matched trailing partial page still costs one fresh page
        (its CoW copy), so need_fresh only discounts *full* matched
        pages; a partial match short of the prompt's end is discarded —
        prefill must rewrite that page, sharing it buys nothing."""
        st = self.store
        if not st._has_pool:
            return 0, [], 0
        lo = st.layout
        need_fresh = lo.pages_for(need_tokens)
        if not self.share_prefix:
            return 0, [], need_fresh
        hit, pages = self.index.match(prompt)
        full = hit // lo.page_size
        if hit % lo.page_size and hit != len(prompt):
            hit = full * lo.page_size
            pages = pages[:full]
        return hit, pages, need_fresh - full

    def make_room(self, need_fresh: int, protect=()) -> bool:
        """True when `need_fresh` pages are (or were made) free. With
        eviction on, cold indexed pages are released LRU-first to close
        the gap — `protect` pins the pages the caller just matched;
        without eviction this is a pure free-list check."""
        st = self.store
        if not st._has_pool or len(st._free) >= need_fresh:
            return True
        if self.evict:
            n = self.index.evict_lru(st, need_fresh, self.evicted_prefixes,
                                     protect)
            if n:
                self.evictions += n
                if self.metrics is not None:
                    self.metrics.counter_inc("serve/evictions", n)
        return len(st._free) >= need_fresh

    def admit(self, slot: int, prompt, need_tokens: int, hit: int,
              pages, step: int) -> int:
        """Map the quoted admission onto `slot`: shared full pages by
        refcount, a fully-matched trailing partial page by CoW, fresh
        pages for the rest; then index this prompt's chain. Returns the
        number of leading pages prefill must skip writing (they already
        hold the prefix)."""
        st = self.store
        if not st._has_pool:
            st.alloc(slot, need_tokens)
            return 0
        lo = st.layout
        self.prompt_tokens += len(prompt)
        full = hit // lo.page_size
        st.alloc(slot, need_tokens, shared=pages[:full])
        owned = st._owned[slot]
        skip = full
        if hit % lo.page_size:
            # whole prompt matched through a partial page: the slot will
            # write generated tokens into its token range — map a copy
            st.copy_page(pages[full], owned[full])
            st.touch([pages[full]], step)
            skip = full + 1
        if hit:
            self.prefix_hit_tokens += hit
            self.pages_shared += full
        if self.share_prefix and self.evicted_prefixes:
            # prefill about to recompute pages eviction reclaimed?
            toks = tuple(int(t) for t in prompt)
            stale = {k for k in self.evicted_prefixes
                     if hit < len(k) <= len(toks) and k == toks[:len(k)]}
            if stale:
                self.readmit_recomputes += 1
                self.evicted_prefixes -= stale
        st.touch(owned, step)
        for p in owned[:full]:
            node = self.index.by_page.get(p)
            if node is not None:
                node.last_touch = step
        if self.share_prefix:
            self.index.insert(st, prompt, owned, step)
        return skip

    # ---- retirement / LRU --------------------------------------------
    def went_cold(self, pages, step: int) -> None:
        """Stamp pages that just lost their last mapping but stay
        resident under an index hold — the LRU clock eviction reads."""
        self.store.touch(pages, step)
        for p in pages:
            node = self.index.by_page.get(p)
            if node is not None:
                node.last_touch = step

    # ---- preemption --------------------------------------------------
    def victim(self, active: dict, step: int, need_fresh: int):
        """Nominate a slot to preempt, or None. FIFO kicks the request
        with the fewest generated tokens (cheapest replay); "deadline"
        kicks the most slack — deadline minus current step minus tokens
        still needed, with no-deadline requests at infinite slack. Only
        victims whose releasable pages (plus the free list, plus cold
        pages when eviction is on) actually cover the shortfall qualify
        — kicking a request that cannot unblock admission helps no
        one."""
        st = self.store
        if not self.preempt or not active:
            return None

        def releasable(s):
            n = 0
            for p in st._owned.get(s, ()):
                if st._ref[p] == 1 and (p not in st._retained
                                        or self.evict):
                    n += 1
            return n

        def cost(item):
            s, slot = item
            if self.policy == "deadline":
                d = slot.req.deadline
                slack = (d - step - (slot.limit - len(slot.stats.tokens))) \
                    if d else float("inf")
                return (-slack, len(slot.stats.tokens), slot.req.rid)
            return (len(slot.stats.tokens), slot.req.rid)

        spare = len(st._free) + (st.pages_cold if self.evict else 0)
        for s, slot in sorted(active.items(), key=cost):
            if spare + releasable(s) >= need_fresh:
                return s
        return None
