"""repro.serve — the serving-side state subsystem.

`repro.serve.cache` owns every byte of KV/SSM decoding state: the
contiguous reference layout, the paged pool + block-table layout, and the
`CacheStore` that accounts for both. `repro.serve.memory` is the policy
layer above it: refcounted prefix sharing with copy-on-write, LRU
eviction of cold indexed pages, and preemption victim selection. See the
module docstrings for the memory model. `repro.serve.router` scales out:
heterogeneous data-parallel replicas (each with its own store, memory
manager, and scheduler) behind a topology-priced dispatch Router.

Router is imported lazily (`from repro.serve.router import Router`) to
keep this package import light — it pulls in the Engine stack.
"""
from repro.serve.cache import (CacheStore, PageLayout, cache_struct,
                               init_cache, init_paged, is_paged,
                               make_layout, paged_struct, serve_dtypes)
from repro.serve.memory import MemoryManager, PrefixIndex

__all__ = ["CacheStore", "MemoryManager", "PageLayout", "PrefixIndex",
           "cache_struct", "init_cache", "init_paged", "is_paged",
           "make_layout", "paged_struct", "serve_dtypes"]
