"""repro.serve — the serving-side state subsystem.

`repro.serve.cache` owns every byte of KV/SSM decoding state: the
contiguous reference layout, the paged pool + block-table layout, and the
`CacheStore` that accounts for both. See its module docstring for the
memory model.
"""
from repro.serve.cache import (CacheStore, PageLayout, cache_struct,
                               init_cache, init_paged, is_paged,
                               make_layout, paged_struct, serve_dtypes)

__all__ = ["CacheStore", "PageLayout", "cache_struct", "init_cache",
           "init_paged", "is_paged", "make_layout", "paged_struct",
           "serve_dtypes"]
