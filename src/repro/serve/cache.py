"""The serve-side cache subsystem: every byte of KV/SSM decoding state.

HetPipe's premise is that per-stage memory is the scarce resource, so the
serve path treats its cache as a managed, accounted object rather than a
worst-case contiguous block. This module is the single owner of cache
layout knowledge; everything else (models.blocks, core.wave, the Engine,
the Scheduler) goes through its API.

Two layouts, one API:

  contiguous   today's `[groups, batch, max_len, KV, hd]` block — the
               reference implementation. `page_size == max_len` paging
               degenerates to it (one page per slot). `cache_struct` /
               `init_cache` build it; `lm.cache_struct` delegates here.

  paged        full-attention K/V live in a fixed pool of pages
               `[groups, num_pages + 1, page_size, KV, hd]` (the extra
               page is a write-off target for unmapped slots) indexed
               through a per-slot block table `block_tab [max_batch,
               pages_per_slot]` (−1 = unmapped). Reads gather a per-row
               page view; writes scatter page-granularly. Fixed-size
               per-slot state (sliding-window ring, SSM/RWKV recurrent
               state, conv/shift tails) keeps the batch-dim layout — it
               does not grow with sequence length, so paging it would buy
               nothing.

`CacheStore` owns the device tree plus host-side page accounting:
`alloc(slot, tokens)` / `free(slot)` move pages between the free list and
a slot's block-table row, `can_alloc` is the Scheduler's admission gate,
`append_rows` absorbs a prefill step's output (page pool wholesale,
per-slot rows copied into their assigned slots), `gather_view` returns the
per-row contiguous view + positions for host-side inspection, and
`stats()` reports page utilization and bytes (the honest per-stage HBM
number the partitioner can price).

Pages are **refcounted**: `alloc(slot, tokens, shared=pages)` maps an
already-resident prefix (another slot's pages, or cold indexed ones) into
the new slot's block table and only draws the remainder from the free
list — the mechanics under `repro.serve.memory`'s prefix sharing. A page
returns to the free list when its last mapping drops *and* no index hold
(`retain`/`release`) keeps it resident; `copy_page` is the copy-on-write
primitive; `last_touch` carries the LRU stamp the eviction policy sorts
by. `pages_in_use` counts **distinct** physical pages — a page mapped
into five block tables is one page of HBM, not five.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

S_AX, T_AX, D_AX = "stage", "tp", "data"

#: per-slot (batch-dim) cache keys — everything that is not the paged pool
#: or the block table. Layout: batch at dim 1 of every leaf.
SLOT_KEYS = ("kv_win", "ssm_state", "conv_tail", "shift")


# ----------------------------------------------------------------------------
# dtypes
# ----------------------------------------------------------------------------
def serve_dtypes(compute_dtype: str, cache_dtype: str = ""):
    """Resolve the string knobs shared by RunConfig/ServeSpec to
    (compute jnp dtype, cache jnp dtype): compute 'bfloat16' | 'float32';
    cache '' (= compute dtype) or 'f8' (fp8 KV). One mapping for every
    consumer (wave steps, input specs, the Engine serve path, CacheStore),
    so a new cache dtype cannot drift between the allocator and the
    compiled step."""
    cdt = jnp.bfloat16 if compute_dtype == "bfloat16" else jnp.float32
    return cdt, {"f8": jnp.float8_e4m3fn, "": cdt}.get(cache_dtype, cdt)


# ----------------------------------------------------------------------------
# contiguous layout (the reference implementation)
# ----------------------------------------------------------------------------
def cache_struct(cfg, batch: int, max_len: int, *, seq_shards: int = 1,
                 dtype=jnp.bfloat16):
    """Returns (cache_shapes pytree of ShapeDtypeStruct, specs pytree).

    Cache group layout (global):
      kv_full [stages*m_full, B, S, KV, hd]   (seq possibly sharded over data)
      kv_win  [stages*m_win,  B, W, KV, hd]
      ssm_state [Lp, B, H, K, P] fp32 ; conv_tail/shift small
    """
    from repro.models.lm import layer_meta
    meta = layer_meta(cfg)
    st = cfg.stages
    Lp = cfg.padded_layers
    kv_tp = T_AX if (cfg.num_kv_heads and cfg.tp > 1
                     and cfg.num_kv_heads % cfg.tp == 0) else None
    batch_ax = D_AX if batch >= 16 else None
    seq_ax = D_AX if seq_shards > 1 else None
    shapes, specs = {}, {}
    hd, KV = cfg.head_dim, cfg.num_kv_heads
    if meta["m_full"] > 0 and cfg.attn_type != "none":
        shp = (st * meta["m_full"], batch, max_len, KV, hd)
        shapes["kv_full"] = tuple(jax.ShapeDtypeStruct(shp, dtype)
                                  for _ in range(2))
        specs["kv_full"] = tuple(P(S_AX, batch_ax, seq_ax, kv_tp, None)
                                 for _ in range(2))
    if meta["m_win"] > 0:
        W = min(cfg.window_size, max_len)
        shp = (st * meta["m_win"], batch, W, KV, hd)
        shapes["kv_win"] = tuple(jax.ShapeDtypeStruct(shp, dtype)
                                 for _ in range(2))
        specs["kv_win"] = tuple(P(S_AX, batch_ax, None, kv_tp, None)
                                for _ in range(2))
    if cfg.ssm_type == "ssd":
        H, N, Pd = cfg.n_ssm_heads, cfg.ssm_state, cfg.d_inner // cfg.n_ssm_heads
        shapes["ssm_state"] = jax.ShapeDtypeStruct((Lp, batch, H, N, Pd),
                                                   jnp.float32)
        specs["ssm_state"] = P(S_AX, batch_ax, None, None, None)
        shapes["conv_tail"] = jax.ShapeDtypeStruct(
            (Lp, batch, 3, cfg.d_inner + 2 * N), dtype)
        specs["conv_tail"] = P(S_AX, batch_ax, None, None)
    if cfg.ssm_type == "rwkv6":
        H = cfg.n_ssm_heads
        hds = cfg.d_model // H
        shapes["ssm_state"] = jax.ShapeDtypeStruct((Lp, batch, H, hds, hds),
                                                   jnp.float32)
        specs["ssm_state"] = P(S_AX, batch_ax, None, None, None)
        shapes["shift"] = jax.ShapeDtypeStruct((Lp, batch, 2, cfg.d_model),
                                               dtype)
        specs["shift"] = P(S_AX, batch_ax, None, None)
    return shapes, specs


def init_cache(cfg, batch: int, max_len: int, *, seq_shards=1,
               dtype=jnp.bfloat16):
    shapes, _ = cache_struct(cfg, batch, max_len, seq_shards=seq_shards,
                             dtype=dtype)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ----------------------------------------------------------------------------
# paged layout
# ----------------------------------------------------------------------------
@dataclass(frozen=True)
class PageLayout:
    """Static geometry of a paged cache pool."""

    max_batch: int
    max_len: int                # logical positions per slot (prompt + gen)
    page_size: int              # tokens per page
    num_pages: int              # usable physical pages in the pool

    @property
    def pages_per_slot(self) -> int:
        return math.ceil(self.max_len / self.page_size)

    @property
    def trash_page(self) -> int:
        """Physical index of the write-off page (block_tab == -1 maps
        here); its contents are never read — every gathered position of an
        unmapped page carries gpos = -1, which decode_attend masks."""
        return self.num_pages

    def pages_for(self, tokens: int) -> int:
        return math.ceil(max(int(tokens), 1) / self.page_size)


def make_layout(max_batch: int, max_len: int, *, page_size: int = 0,
                max_pages: int = 0) -> PageLayout:
    """page_size 0 -> max_len (contiguous degenerate: one page per slot);
    max_pages 0 -> the worst case max_batch * pages_per_slot."""
    ps = page_size or max_len
    if not 1 <= ps <= max_len:
        raise ValueError(f"page_size {ps} outside [1, max_len={max_len}]")
    pps = math.ceil(max_len / ps)
    np_total = max_pages or max_batch * pps
    if np_total < pps:
        raise ValueError(
            f"max_pages={np_total} cannot hold one worst-case request "
            f"({pps} pages of {ps} tokens for max_len={max_len}); the "
            f"Scheduler could never admit it")
    return PageLayout(max_batch, max_len, ps, np_total)


def paged_struct(cfg, layout: PageLayout, *, dtype=jnp.bfloat16):
    """(shapes, specs) for the paged tree: the contiguous struct with
    kv_full re-homed to the page pool plus the block table. The pool is
    stage-sharded exactly like the contiguous group; the block table is
    replicated (every stage resolves the same logical -> physical map)."""
    from repro.models.lm import layer_meta
    shapes, specs = cache_struct(cfg, layout.max_batch, layout.max_len,
                                 dtype=dtype)
    meta = layer_meta(cfg)
    if "kv_full" in shapes:
        st = cfg.stages
        kv_tp = T_AX if (cfg.num_kv_heads and cfg.tp > 1
                         and cfg.num_kv_heads % cfg.tp == 0) else None
        shp = (st * meta["m_full"], layout.num_pages + 1, layout.page_size,
               cfg.num_kv_heads, cfg.head_dim)
        shapes["kv_full"] = tuple(jax.ShapeDtypeStruct(shp, dtype)
                                  for _ in range(2))
        specs["kv_full"] = tuple(P(S_AX, None, None, kv_tp, None)
                                 for _ in range(2))
    shapes["block_tab"] = jax.ShapeDtypeStruct(
        (layout.max_batch, layout.pages_per_slot), jnp.int32)
    specs["block_tab"] = P(None, None)
    return shapes, specs


def init_paged(cfg, layout: PageLayout, *, dtype=jnp.bfloat16):
    shapes, _ = paged_struct(cfg, layout, dtype=dtype)
    tree = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    tree["block_tab"] = jnp.full(shapes["block_tab"].shape, -1, jnp.int32)
    return tree


def is_paged(cache) -> bool:
    return cache is not None and "block_tab" in cache


# ----------------------------------------------------------------------------
# traced page ops (called from models.blocks inside jit / the pipeline scan)
# ----------------------------------------------------------------------------
def _phys(tab, trash):
    return jnp.where(tab >= 0, tab, trash)


def page_view(pool, i, tab):
    """Gather one group's per-row contiguous view through the block table.

    pool [m, NP+1, ps, KV, hd]; tab [B, pps]. Returns (view [B, pps*ps,
    KV, hd], gpos [B, pps*ps]) where gpos is the global position of each
    gathered slot, -1 for unmapped pages (decode_attend masks those)."""
    B, pps = tab.shape
    ps = pool.shape[2]
    grp = pool[i]                                       # [NP+1, ps, KV, hd]
    view = grp[_phys(tab, pool.shape[1] - 1)]           # [B, pps, ps, KV, hd]
    view = view.reshape(B, pps * ps, *pool.shape[3:])
    gpos = jnp.arange(pps * ps, dtype=jnp.int32)[None, :]
    gpos = jnp.where(jnp.repeat(tab >= 0, ps, axis=1), gpos, -1)
    return view, gpos


def page_write_token(pool, i, tab, pos, new_row, sel):
    """Decode-time single-token scatter: row b's token lands in the page
    holding logical position pos[b]. pool [m, NP+1, ps, KV, hd]; tab
    [B, pps]; pos, sel [B]; new_row [B, 1, KV, hd]. Rows with sel False or
    an unmapped page write to the trash page instead (never read)."""
    B, pps = tab.shape
    ps = pool.shape[2]
    trash = pool.shape[1] - 1
    lp = jnp.clip(pos // ps, 0, pps - 1)
    off = jnp.clip(pos, 0, None) % ps
    phys = tab[jnp.arange(B), lp]                       # [B]
    phys = jnp.where(sel & (phys >= 0), phys, trash)
    return pool.at[i, phys, off].set(new_row[:, 0].astype(pool.dtype))


def page_write_prompt(pool, i, tab, new_kv, sel, lens=None):
    """Prefill-time page-granular scatter of a whole prompt. new_kv
    [B, S, KV, hd] (positions 0..S-1); sel [B] or scalar; lens [B] or None
    (positions >= lens[b] keep the page's previous contents — variable-
    length prompts write only their real tokens). Rows with sel False or
    unmapped pages scatter into the trash page."""
    B, S = new_kv.shape[:2]
    ps = pool.shape[2]
    trash = pool.shape[1] - 1
    pp_in = math.ceil(S / ps)
    pad = pp_in * ps - S
    kv = jnp.pad(new_kv, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else new_kv
    kv = kv.reshape(B, pp_in, ps, *new_kv.shape[2:])
    tabp = tab[:, :pp_in]
    sel_b = jnp.broadcast_to(jnp.asarray(sel), (B,))
    phys = jnp.where(sel_b[:, None] & (tabp >= 0), tabp, trash)  # [B, pp_in]
    gpos = jnp.arange(pp_in * ps).reshape(pp_in, ps)             # [pp_in, ps]
    live = (gpos[None] < S) if lens is None else \
        (gpos[None] < jnp.minimum(lens, S)[:, None, None])       # [B,pp,ps]
    old = pool[i][phys]                                 # [B, pp_in, ps, KV, hd]
    upd = jnp.where(live[..., None, None], kv.astype(pool.dtype), old)
    return pool.at[i, phys].set(upd)


# ----------------------------------------------------------------------------
# contiguous single-position writes (the reference implementation the paged
# scatter is parity-tested against; used by the aligned generate() path)
# ----------------------------------------------------------------------------
def upd_kv(group, i, pos_idx, new_row, sel):
    """Single-position conditional cache write: group [m, B, S, KV, hd],
    new_row [B, 1, KV, hd]. Touches only the written row (in-place on TPU)."""
    start = (i, 0, pos_idx, 0, 0)
    old = jax.lax.dynamic_slice(group, start, (1,) + new_row.shape)
    upd = jnp.where(sel, new_row.astype(group.dtype)[None], old)
    return jax.lax.dynamic_update_slice(group, upd, start)


def upd_kv_rows(group, i, pos_idx, new_row, sel):
    """Per-row conditional cache write for continuous batching: each batch
    row b lands at its own position pos_idx[b]. group [m, B, S, KV, hd],
    new_row [B, 1, KV, hd], pos_idx/sel [B]."""
    rows = jnp.arange(group.shape[1])
    old = group[i, rows, pos_idx]                       # [B, KV, hd]
    upd = jnp.where(sel[:, None, None],
                    new_row[:, 0].astype(group.dtype), old)
    return group.at[i, rows, pos_idx].set(upd)


# ----------------------------------------------------------------------------
# pipeline microbatch views (batch at dim 1 of per-slot leaves; the paged
# pool and the block table are shared across microbatches)
# ----------------------------------------------------------------------------
def slice_mb(cache, j, mb):
    """The per-microbatch cache view the pipeline stage computes on: per-
    slot leaves sliced to rows [j*mb, (j+1)*mb); the page pool passes
    through whole (microbatches own disjoint pages, writes are scatters);
    the block table is row-sliced alongside the batch."""
    if cache is None:
        return None
    paged = is_paged(cache)
    out = {}
    for key, v in cache.items():
        if key == "block_tab":
            out[key] = jax.lax.dynamic_slice_in_dim(v, j * mb, mb, axis=0)
        elif paged and key == "kv_full":
            out[key] = v
        else:
            out[key] = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, j * mb, mb, axis=1),
                v)
    return out


def update_mb(cache, new_rows, j, mb, valid):
    """Write a stage's per-microbatch cache updates back: per-slot leaves
    via dynamic_update (masked by tick validity), the page pool wholesale
    (its scatters already routed dead rows to the trash page, and the
    caller only runs this on live ticks), the block table untouched (it is
    read-only inside the step)."""
    paged = is_paged(cache)
    out = {}
    for key, v in cache.items():
        if key == "block_tab":
            out[key] = v
        elif paged and key == "kv_full":
            out[key] = new_rows[key]
        else:
            def upd(a, n):
                old = jax.lax.dynamic_slice_in_dim(a, j * mb, mb, axis=1)
                n = jnp.where(valid, n.astype(a.dtype), old)
                return jax.lax.dynamic_update_slice_in_dim(a, n, j * mb,
                                                           axis=1)
            out[key] = jax.tree.map(upd, v, new_rows[key])
    return out


# ----------------------------------------------------------------------------
# the store
# ----------------------------------------------------------------------------
class CacheStore:
    """Owns one serve cache: the device tree plus host-side page
    accounting. The Scheduler allocates pages at admission and frees them
    at retirement; the Engine's serve steps read/write the tree.

    shardings: optional pytree of NamedShardings matching the tree (spmd
    placement); None keeps plain host-backed arrays (threads backend)."""

    def __init__(self, cfg, layout: PageLayout, *, dtype=jnp.bfloat16,
                 shardings=None):
        self.cfg, self.layout, self.dtype = cfg, layout, dtype
        self._shardings = shardings
        tree = init_paged(cfg, layout, dtype=dtype)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        self.tree = tree
        # attention-free / all-windowed families have no full-attention KV
        # group: their decoding state is fixed-size per slot, so there is
        # no pool to ration — alloc/free degrade to slot bookkeeping and
        # can_alloc never blocks admission on phantom pages
        self._has_pool = "kv_full" in tree
        self._tab = np.full((layout.max_batch, layout.pages_per_slot), -1,
                            np.int32)
        self._free = list(range(layout.num_pages)) if self._has_pool else []
        self._owned: dict[int, list[int]] = {}
        self.peak_pages = 0
        # refcounted sharing (repro.serve.memory drives the policy):
        # _ref[p] counts block-table mappings of page p; _retained marks
        # pages the prefix index holds resident at refcount zero (cold —
        # evictable, not free); last_touch is the LRU stamp the eviction
        # policy orders cold pages by; cow_copies counts copy-on-write
        # page duplications taken
        self._ref = np.zeros(layout.num_pages, np.int32)
        self._retained: set[int] = set()
        self.last_touch = np.zeros(layout.num_pages, np.int64)
        self.cow_copies = 0

    # ---- accounting --------------------------------------------------
    @property
    def pages_total(self) -> int:
        return self.layout.num_pages if self._has_pool else 0

    @property
    def pages_in_use(self) -> int:
        """Distinct physical pages not on the free list (mapped by at
        least one slot, or held cold by the prefix index). A page shared
        across N block tables counts once — it is one page of HBM."""
        return self.pages_total - len(self._free)

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_cold(self) -> int:
        """Resident pages no slot maps: index-retained, evictable."""
        return sum(1 for p in self._retained if self._ref[p] == 0)

    def can_alloc(self, tokens: int, shared: int = 0) -> bool:
        """Admission gate: `shared` pages of the request come mapped from
        the prefix index, only the remainder draws on the free list."""
        if not self._has_pool:
            return True
        return len(self._free) >= self.layout.pages_for(tokens) - shared

    def alloc(self, slot: int, tokens: int, shared=()) -> None:
        """Map pages for `tokens` logical positions onto `slot`. The
        leading `shared` pages are already-resident prefix pages
        (refcounts bumped, nothing drawn from the free list); the
        remainder comes fresh from the pool. Raises when the pool is
        exhausted — the Scheduler gates admission on can_alloc() instead
        of over-reserving."""
        lo = self.layout
        if slot in self._owned:
            raise ValueError(f"slot {slot} already holds pages; free() it "
                             f"before re-allocating")
        if tokens > lo.max_len:
            raise ValueError(f"{tokens} tokens exceed max_len={lo.max_len}")
        if not self._has_pool:
            if shared:
                raise ValueError("shared prefix pages need a kv_full pool; "
                                 "this family's state is per-slot only")
            self._owned[slot] = []
            return
        shared = list(shared)
        need = lo.pages_for(tokens)
        if len(shared) > need:
            raise ValueError(f"{len(shared)} shared pages exceed the "
                             f"{need} pages {tokens} tokens need")
        for p in shared:
            if self._ref[p] == 0 and p not in self._retained:
                raise ValueError(f"shared page {p} is not resident (free "
                                 f"list); the prefix index is stale")
        fresh_n = need - len(shared)
        if fresh_n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {fresh_n} fresh pages for "
                f"{tokens} tokens ({len(shared)} shared), "
                f"{len(self._free)}/{lo.num_pages} free")
        fresh = self._free[:fresh_n]
        del self._free[:fresh_n]
        pages = shared + fresh
        for p in pages:
            self._ref[p] += 1
        self._owned[slot] = pages
        self._tab[slot, :] = -1
        self._tab[slot, :need] = pages
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        self._sync_tab()

    def free(self, slot: int) -> list:
        """Unmap `slot`'s pages. Each page's refcount drops; pages
        reaching zero return to the free list unless the prefix index
        retains them — those go *cold* (resident, evictable) and are
        returned so the caller can stamp their LRU clock."""
        pages = self._owned.pop(slot, None)
        if not pages:
            return []
        cold = []
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                if p in self._retained:
                    cold.append(p)
                else:
                    self._free.append(p)
        self._free.sort()
        self._tab[slot, :] = -1
        self._sync_tab()
        return cold

    # ---- sharing / eviction mechanics (policy in repro.serve.memory) --
    def retain(self, page: int) -> None:
        """Prefix-index hold: keep `page` resident when its last slot
        mapping drops (cold, evictable — not free)."""
        self._retained.add(page)

    def release(self, page: int) -> bool:
        """Drop the index hold on `page` (eviction). Returns True when
        the page went back to the free list — i.e. no slot still maps
        it; a mapped page frees later, on its last unmap."""
        self._retained.discard(page)
        if self._ref[page] == 0 and page not in self._free:
            self._free.append(page)
            self._free.sort()
            return True
        return False

    def copy_page(self, src: int, dst: int) -> None:
        """Copy-on-write primitive: device-copy page `src` -> `dst`
        across the K and V pools of every layer group. The writer maps
        the copy; the shared original stays immutable."""
        k, v = self.tree["kv_full"]
        self.tree["kv_full"] = (k.at[:, dst].set(k[:, src]),
                                v.at[:, dst].set(v[:, src]))
        self.cow_copies += 1

    def touch(self, pages, step: int) -> None:
        """Stamp pages' last_touch with the current decode step — the
        LRU clock eviction orders cold pages by."""
        for p in pages:
            self.last_touch[p] = step

    def _sync_tab(self) -> None:
        tab = jnp.asarray(self._tab)
        if self._shardings is not None:
            tab = jax.device_put(tab, self._shardings["block_tab"])
        self.tree["block_tab"] = tab

    # ---- views / updates ---------------------------------------------
    def prefill_input(self, slots, skip_pages=None):
        """The cache tree a prefill step writes into: the live page pool,
        a block table whose row j maps to slots[j]'s pages (-1 rows for
        unused prefill rows), and fresh zeroed per-slot state (computed
        into prefill rows, then adopted via append_rows).

        skip_pages[j] masks row j's first N page entries to -1 *in this
        prefill view only*: those pages hold a shared, already-written
        prefix, so the row's recomputed K/V for them routes to the trash
        page instead of rewriting shared state. The store's real block
        table keeps the mapping — decode reads the shared pages."""
        lo = self.layout
        tab = np.full((lo.max_batch, lo.pages_per_slot), -1, np.int32)
        for j, s in enumerate(slots):
            tab[j] = self._tab[s]
            if skip_pages is not None and skip_pages[j]:
                tab[j, :skip_pages[j]] = -1
        fresh = init_paged(self.cfg, self.layout, dtype=self.dtype)
        fresh["block_tab"] = jnp.asarray(tab)
        if "kv_full" in self.tree:
            fresh["kv_full"] = self.tree["kv_full"]
        if self._shardings is not None:
            fresh = jax.device_put(fresh, self._shardings)
        return fresh

    def append_rows(self, out_tree, pairs) -> None:
        """Absorb a prefill step's output: the page pool is taken
        wholesale (its scatters landed in the admitted slots' pages);
        per-slot leaves are row-copied src -> dst for each (src, dst) in
        pairs — whole-row replacement also clears any stale ring/SSM
        state from a slot's previous occupant."""
        if "kv_full" in self.tree:
            self.tree["kv_full"] = out_tree["kv_full"]
        if not pairs:
            return
        srcs = np.array([s for s, _ in pairs])
        dsts = np.array([d for _, d in pairs])
        for key in SLOT_KEYS:
            if key in self.tree:
                self.tree[key] = jax.tree.map(
                    lambda big, f: big.at[:, dsts].set(f[:, srcs]),
                    self.tree[key], out_tree[key])

    def update(self, out_tree) -> None:
        """Absorb a decode step's full output tree (block table is
        authoritative on the host side and kept as-is)."""
        tab = self.tree["block_tab"]
        self.tree = dict(out_tree)
        self.tree["block_tab"] = tab

    def gather_view(self, group_i: int = 0):
        """Host-side per-row contiguous view of one kv_full group (debug /
        tests): (k [B, pps*ps, KV, hd], v, gpos [B, pps*ps])."""
        k, v = self.tree["kv_full"]
        tab = jnp.asarray(self._tab)
        kv_view, gpos = page_view(k, group_i, tab)
        vv_view, _ = page_view(v, group_i, tab)
        return kv_view, vv_view, gpos

    # ---- reporting ---------------------------------------------------
    def stats(self) -> dict:
        """Page accounting + bytes: the per-stage HBM truth the partitioner
        and the ServeReport read."""
        lo = self.layout
        page_bytes = 0
        if "kv_full" in self.tree:
            k, _ = self.tree["kv_full"]
            # one page across both K and V pools, all layer groups
            page_bytes = 2 * k.shape[0] * lo.page_size * int(
                np.prod(k.shape[3:])) * k.dtype.itemsize
        slot_bytes = 0
        for key in SLOT_KEYS:
            if key in self.tree:
                slot_bytes += sum(int(l.nbytes) for l in
                                  jax.tree.leaves(self.tree[key]))
        return {
            "page_size": lo.page_size,
            "pages_total": self.pages_total,
            "pages_in_use": self.pages_in_use,
            "pages_free": len(self._free),
            "pages_cold": self.pages_cold,
            "pages_shared": int((self._ref > 1).sum()) if self._has_pool
            else 0,
            "cow_copies": self.cow_copies,
            "peak_pages": self.peak_pages,
            "page_bytes": page_bytes,
            "pool_bytes": page_bytes * self.pages_total,
            "slot_state_bytes": slot_bytes,
            "utilization": (self.pages_in_use / self.pages_total
                            if self.pages_total else 0.0),
        }
