"""Pallas TPU grouped (expert-batched) matmul for MoE expert FFNs.

Grid (e, c_block, f_block, d_block): one [bc x bd] x [bd x bf] MXU tile per
step with f32 accumulation in VMEM scratch across d blocks (innermost axis).
Tiles default to 128 (MXU-aligned); the accumulator is written once at the
last d block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_sc, *, nd):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _reset():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    x = x_ref[0].astype(jnp.float32)        # [bc, bd]
    w = w_ref[0].astype(jnp.float32)        # [bd, bf]
    acc_sc[...] += jax.lax.dot(x, w)

    @pl.when(di == nd - 1)
    def _write():
        o_ref[0] = acc_sc[...].astype(o_ref.dtype)


def grouped_matmul(x, w, *, block_c=128, block_f=128, block_d=128,
                   interpret=False):
    """x [E, C, d] @ w [E, d, f] -> [E, C, f]."""
    E, C, d = x.shape
    f = w.shape[-1]

    def fit(b, s):
        b = min(b, s)
        while s % b:
            b -= 1
        return b

    bc, bf, bd = fit(block_c, C), fit(block_f, f), fit(block_d, d)
    nd = d // bd
    kernel = functools.partial(_gmm_kernel, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(E, C // bc, f // bf, nd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, bd, bf), lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
