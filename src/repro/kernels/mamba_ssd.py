"""Pallas TPU chunked SSD (Mamba-2 style selective state space).

Grid (b, h, chunk), chunk innermost; the [N, P] f32 state persists in VMEM
scratch. Scalar-per-head decay makes the intra-chunk decay matrix
L[t,s] = exp(cs_t - cs_s) numerically safe (always <= 1) at any chunk size;
chunk 64 keeps tiles MXU-friendly while the state tile (N x P = 16 x 64) is
VPU-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, st_ref, state_sc,
                *, C, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _reset():
        state_sc[...] = jnp.zeros_like(state_sc)

    x = x_ref[0, 0].astype(jnp.float32)              # [C, P]
    dt = dt_ref[0, 0].astype(jnp.float32)            # [C]
    Bm = b_ref[0].astype(jnp.float32)                # [C, N]
    Cm = c_ref[0].astype(jnp.float32)
    a = a_ref[0]                                     # scalar < 0

    la = dt * a                                      # [C] log-decay
    cs = jnp.cumsum(la)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))   # [C, C]
    L = jnp.exp(cs[:, None] - cs[None, :])
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    L = jnp.where(ti >= si, L, 0.0)
    y = jax.lax.dot(cb * L * dt[None, :], x)         # intra-chunk
    y += jax.lax.dot(Cm * jnp.exp(cs)[:, None], state_sc[...])   # inter
    dec = jnp.exp(cs[-1] - cs) * dt                  # [C]
    state_sc[...] = jnp.exp(cs[-1]) * state_sc[...] + jax.lax.dot_general(
        Bm * dec[:, None], x, (((0,), (0,)), ((), ())))
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _write_state():
        st_ref[0, 0] = state_sc[...]


def ssd_chunked(x, dt, B_, C_, a, *, chunk=64, interpret=False):
    """x [B,H,S,P]; dt [B,H,S]; B_/C_ [B,S,N]; a [H] < 0.
    Returns (y [B,H,S,P], final_state [B,H,N,P] f32)."""
    B, H, S, Pd = x.shape
    N = B_.shape[-1]
    C = min(chunk, S)
    while S % C:
        C -= 1
    n = S // C
    kernel = functools.partial(_ssd_kernel, C=C, n_chunks=n)
    y, st = pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, C, Pd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, C, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, C, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, C, Pd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, Pd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, Pd), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, Pd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, Pd), jnp.float32)],
        interpret=interpret,
    )(x, dt, B_, C_, a)
    return y, st
