"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Layouts (kernel-native):
  attention: q [B, H, Sq, hd]; k, v [B, KV, Sk, hd]  (GQA: G = H // KV)
  rwkv6:     r,k,v,w [B, H, S, hd] (w = log-decay <= 0); u [H, hd]
  ssd:       x [B, H, S, P]; dt [B, H, S]; B_,C_ [B, S, N]; a [H] < 0
  gmm:       x [E, C, d]; w [E, d, f]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0):
    B, H, Sq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bkcd->bkgqc", qf, kf) * hd ** -0.5
    Sk = k.shape[2]
    gq = jnp.arange(Sq)[:, None] + (Sk - Sq)      # align ends (decode tail)
    gk = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= gq >= gk
    if window > 0:
        mask &= (gq - gk) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqc,bkcd->bkgqd", p, vf)
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def decode_ref(q1, k, v, length, *, window=0):
    """q1 [B, H, hd]; k/v [B, KV, S, hd]; attend to positions < length.

    `length` is a scalar or a per-row [B] vector. Fully-masked rows
    (length == 0) return zeros — the same contract as the Pallas kernel's
    `l = max(l, 1e-30)` guard (a plain softmax would degenerate to a
    uniform average over uninitialized V rows)."""
    B, H, hd = q1.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    qf = q1.astype(jnp.float32).reshape(B, KV, G, hd)
    s = jnp.einsum("bkgd,bkcd->bkgc", qf, k.astype(jnp.float32)) * hd ** -0.5
    pos = jnp.arange(S)[None, None, None, :]
    lens = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1),
                            (B,))[:, None, None, None]
    valid = pos < lens
    if window > 0:
        valid &= pos >= (lens - window)
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(valid, jnp.exp(s - m), 0.0)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgc,bkcd->bkgd", p / l, v.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q1.dtype)


def decode_paged_ref(q1, k_pool, v_pool, block_tab, lengths, *, layer=0):
    """Oracle for flash_decode_paged: gather the paged pool into a
    contiguous per-row view (exactly the materialization the fused kernel
    avoids), then run decode_ref with per-row lengths.

    q1 [B,H,hd]; pools [groups, num_pages+1, page_size, KV, hd] (last page
    = trash); block_tab [B, pages_per_slot] int32 (-1 = unmapped ->
    trash); lengths scalar or [B]."""
    B = q1.shape[0]
    groups, P1, ps, KV, hd = k_pool.shape
    phys = jnp.where(block_tab >= 0, block_tab, P1 - 1)     # [B, npg]

    def view(pool):
        pages = pool[layer][phys]                           # [B,npg,ps,KV,hd]
        return pages.reshape(B, -1, KV, hd).transpose(0, 2, 1, 3)

    return decode_ref(q1, view(k_pool), view(v_pool), lengths, window=0)


def rwkv6_ref(r, k, v, w, u, state0=None):
    """Sequential WKV6 recurrence. Returns (y [B,H,S,hd], final_state)."""
    B, H, S, hd = r.shape
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if state0 is None:
        state0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(st, xs):
        r_, k_, v_, w_ = xs                      # [B,H,hd]
        kv = jnp.einsum("bhi,bhj->bhij", k_, v_)
        y = jnp.einsum("bhi,bhij->bhj", r_, st + uf[None, :, :, None] * kv)
        st = jnp.exp(w_)[..., None] * st + kv
        return st, y

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (rf, kf, vf, wf))
    stT, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype), stT


def ssd_ref(x, dt, B_, C_, a, state0=None):
    """Sequential SSD. x [B,H,S,P], dt [B,H,S], B_/C_ [B,S,N], a [H]<0.
    Returns (y [B,H,S,P], final_state [B,H,N,P])."""
    B, H, S, Pd = x.shape
    N = B_.shape[-1]
    if state0 is None:
        state0 = jnp.zeros((B, H, N, Pd), jnp.float32)

    def step(h, xs):
        x_, dt_, b_, c_ = xs                     # [B,H,P],[B,H],[B,N],[B,N]
        dec = jnp.exp(dt_ * a[None, :])
        h = dec[..., None, None] * h + jnp.einsum(
            "bn,bh,bhp->bhnp", b_, dt_, x_)
        y = jnp.einsum("bn,bhnp->bhp", c_, h)
        return h, y

    xs = (x.transpose(2, 0, 1, 3).astype(jnp.float32),
          dt.transpose(2, 0, 1).astype(jnp.float32),
          B_.transpose(1, 0, 2).astype(jnp.float32),
          C_.transpose(1, 0, 2).astype(jnp.float32))
    hT, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 2, 0, 3).astype(x.dtype), hT


def gmm_ref(x, w):
    """Grouped (expert-batched) matmul: [E,C,d] x [E,d,f] -> [E,C,f]."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)
