"""jit'd wrappers over the Pallas kernels with a backend switch.

KERNEL_BACKEND:
  "ref"       — pure-jnp oracles (default on CPU / in the dry-run: Mosaic
                cannot lower for the CPU backend)
  "interpret" — pallas_call(interpret=True): the kernel body executed in
                Python — used by the correctness sweeps in tests/
  "tpu"       — compiled Mosaic kernels (the deployment target)

Layout adapters between the model convention ([B, S, H, hd]) and the kernel
convention ([B, H, S, hd]) live here.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.kernels.mamba_ssd import ssd_chunked
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.rwkv6_scan import rwkv6_chunked

BACKENDS = ("ref", "interpret", "tpu")

KERNEL_BACKEND = "ref"


def check_backend(name: str) -> str:
    if name not in BACKENDS:
        raise ValueError(
            f"unknown kernel backend {name!r}: expected one of {BACKENDS}")
    return name


def set_backend(name: str):
    global KERNEL_BACKEND
    KERNEL_BACKEND = check_backend(name)


@contextlib.contextmanager
def use_backend(name: str):
    """Scoped backend switch — restores the previous backend on exit, so
    parity tests cannot leak a process-global setting into each other."""
    global KERNEL_BACKEND
    prev = KERNEL_BACKEND
    set_backend(name)
    try:
        yield
    finally:
        KERNEL_BACKEND = prev


def _interp():
    return KERNEL_BACKEND == "interpret"


def attention(q, k, v, *, causal=True, window=0, backend=None):
    """Model layout: q [B,S,H,hd], k/v [B,S,KV,hd] -> [B,S,H,hd]."""
    be = backend or KERNEL_BACKEND
    qT = q.transpose(0, 2, 1, 3)
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    if be == "ref":
        o = kref.attention_ref(qT, kT, vT, causal=causal, window=window)
    else:
        o = flash_attention_fwd(qT, kT, vT, causal=causal, window=window,
                                interpret=(be == "interpret"))
    return o.transpose(0, 2, 1, 3)


def decode_attention(q1, k, v, length, *, window=0, backend=None):
    """q1 [B,H,hd]; k/v [B,KV,S,hd] kernel-native; length scalar or [B]."""
    be = backend or KERNEL_BACKEND
    if be == "ref":
        return kref.decode_ref(q1, k, v, length, window=window)
    return flash_decode(q1, k, v, length, window=window,
                        interpret=(be == "interpret"))


def decode_attention_paged(q1, k_pool, v_pool, block_tab, lengths, *,
                           layer=0, backend=None):
    """Fused paged decode: pools [groups, num_pages+1, page_size, KV, hd]
    walked through block_tab [B, pages_per_slot] with per-row lengths.
    The "ref" backend gathers the paged view first (the materialization the
    kernel backends avoid)."""
    be = backend or KERNEL_BACKEND
    if be == "ref":
        return kref.decode_paged_ref(q1, k_pool, v_pool, block_tab, lengths,
                                     layer=layer)
    return flash_decode_paged(q1, k_pool, v_pool, block_tab, lengths,
                              layer=layer, interpret=(be == "interpret"))


def rwkv6(r, k, v, w, u, *, backend=None):
    be = backend or KERNEL_BACKEND
    if be == "ref":
        return kref.rwkv6_ref(r, k, v, w, u)
    return rwkv6_chunked(r, k, v, w, u, interpret=(be == "interpret"))


def ssd(x, dt, B_, C_, a, *, backend=None):
    be = backend or KERNEL_BACKEND
    if be == "ref":
        return kref.ssd_ref(x, dt, B_, C_, a)
    return ssd_chunked(x, dt, B_, C_, a, interpret=(be == "interpret"))


def gmm(x, w, *, backend=None):
    be = backend or KERNEL_BACKEND
    if be == "ref":
        return kref.gmm_ref(x, w)
    return grouped_matmul(x, w, interpret=(be == "interpret"))
