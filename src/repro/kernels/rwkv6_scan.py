"""Pallas TPU chunked WKV6 recurrence (RWKV6 'Finch', per-channel decay).

Grid (b, h, chunk) with the chunk axis innermost; the [hd, hd] f32 state
persists in VMEM scratch across chunks (reset at chunk 0). Within a chunk the
per-channel decay factorizes into row/col scalings of the score matrix
(r'_t = r_t * exp(cs_{t-1}), k'_s = k_s * exp(-cs_s)), turning the recurrence
into two MXU matmuls + a strictly-lower-triangular mask. Chunk size is capped
at 16 so exp(-cs) stays within f32 range under the model's clamped log-decay
(|logw| <= 4 per step; see repro.models.ssm._LOGW_CLIP and DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, st_ref, state_sc,
                 *, C, hd, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _reset():
        state_sc[...] = jnp.zeros_like(state_sc)

    r = r_ref[0, 0].astype(jnp.float32)              # [C, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)              # log-decay <= 0
    u = u_ref[0].astype(jnp.float32)                 # [hd]

    cs = jnp.cumsum(w, axis=0)                       # [C, hd]
    cs_prev = cs - w
    r_p = r * jnp.exp(cs_prev)
    k_p = k * jnp.exp(-cs)

    scores = jax.lax.dot_general(r_p, k_p, (((1,), (1,)), ((), ())))
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    scores = jnp.where(ti > si, scores, 0.0)         # strict lower
    y = jax.lax.dot(scores, v)
    diag = jnp.sum(r * u[None, :] * k, axis=1)       # u-bonus on t == s
    y += diag[:, None] * v
    y += jax.lax.dot(r_p, state_sc[...])             # inter-chunk

    state_sc[...] = jnp.exp(cs[-1])[:, None] * (
        state_sc[...] + jax.lax.dot_general(k_p, v, (((0,), (0,)), ((), ()))))
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _write_state():
        st_ref[0, 0] = state_sc[...]


def rwkv6_chunked(r, k, v, w, u, *, chunk=16, interpret=False):
    """r,k,v,w [B,H,S,hd] (w = log-decay <= 0); u [H,hd].
    Returns (y [B,H,S,hd], final_state [B,H,hd,hd] f32)."""
    B, H, S, hd = r.shape
    C = min(chunk, S)
    while S % C:
        C -= 1
    n = S // C
    kernel = functools.partial(_wkv6_kernel, C=C, hd=hd, n_chunks=n)
    y, st = pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, C, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, C, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, st
