"""Pallas TPU flash-decoding: one query token vs. a long KV cache.

Two entry points share the online-softmax inner loop:

  flash_decode        contiguous KV [B, KV, S, hd]; `length` is a scalar or
                      a per-row [B] vector (continuous batching: each row at
                      its own depth). Cache lengths that do not divide the
                      k-block are padded with dead (masked) positions up to
                      a block multiple instead of silently shrinking the
                      block toward 1 (which destroyed MXU alignment for
                      prime cache lengths).
  flash_decode_paged  the serve KV pool [groups, num_pages+1, page_size,
                      KV, hd] indexed *in the kernel* through the per-slot
                      int32 block table: the table and the per-row lengths
                      ride as scalar-prefetch operands and the table drives
                      the pool BlockSpec index map, so batched decode at
                      mixed depths never materializes a contiguous per-row
                      KV view (`CacheStore.gather_view` /
                      `cache.page_view` stay debug-only). Unmapped table
                      entries (-1) resolve to the trash page and are
                      masked; rows with length == 0 emit zeros.

Grid (b, kv_head, k_block), k_block innermost; the GQA group's G query rows
ride together as a [G, hd] tile (G <= 8 for the assigned archs — a VPU-sized
tile; the matmuls are [G,hd]x[hd,bk], MXU-aligned on bk and hd). Accumulators
(m, l, acc over G rows) persist in VMEM scratch; blocks beyond the row's
`length` (the current cache fill) or outside the sliding window are skipped
with pl.when — decode cost scales with the live cache, not the allocated
one. Fully-masked rows (length == 0) emit zeros: the contract
`kernels/ref.py:decode_ref` mirrors.
"""
from __future__ import annotations

import functools
import logging

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

logger = logging.getLogger(__name__)


def _row_lengths(length, B):
    """Scalar or [B] -> [B] int32 per-row lengths."""
    lens = jnp.asarray(length, jnp.int32).reshape(-1)
    if lens.shape[0] not in (1, B):
        raise ValueError(
            f"length must be a scalar or a [B]={B} vector, got "
            f"shape {jnp.asarray(length).shape}")
    return jnp.broadcast_to(lens, (B,))


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                   scale, window, bk, nk):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    k_start = ki * bk
    length = len_ref[b]

    @pl.when(ki == 0)
    def _reset():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    live = k_start < length
    if window > 0:
        live = jnp.logical_and(live, k_start + bk > length - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        gk = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = gk < length
        if window > 0:
            mask &= gk >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(p, v)
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(q1, k, v, length, *, window=0, block_k=256,
                 interpret=False):
    """q1 [B,H,hd]; k,v [B,KV,S,hd]; length scalar or [B] int32 (tokens live
    in each row's cache). Rows with length == 0 return zeros. Returns
    [B,H,hd]."""
    B, H, hd = q1.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_k, S)
    if S % bk:
        # pad the KV view with dead positions up to a block multiple (they
        # sit at gk >= S >= length, so the length mask kills them) rather
        # than shrinking bk toward 1 and destroying MXU alignment
        pad = bk - S % bk
        logger.warning(
            "flash_decode: cache length %d is not a multiple of block_k=%d; "
            "padding %d dead (masked) positions instead of degrading the "
            "block size", S, bk, pad)
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        S += pad
    nk = S // bk
    qg = q1.reshape(B, KV, G, hd)
    lens = _row_lengths(length, B)

    kernel = functools.partial(_decode_kernel, scale=hd ** -0.5,
                               window=window, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q1.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg, k, v)
    return out.reshape(B, H, hd)


# ----------------------------------------------------------------------------
# Paged decode: the block-table walk fused into the BlockSpec index map
# ----------------------------------------------------------------------------
def _paged_kernel(lay_ref, tab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_sc, l_sc, acc_sc, *, scale, ps, npg):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    length = len_ref[b]

    @pl.when(pi == 0)
    def _reset():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    start = pi * ps
    # pages at/after the row's fill or unmapped (-1 -> trash) are dead;
    # skipping them keeps decode cost proportional to the live cache
    live = jnp.logical_and(start < length, tab_ref[b, pi] >= 0)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, hd]
        k = k_ref[0, 0, :, 0].astype(jnp.float32)        # [ps, hd]
        v = v_ref[0, 0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        gk = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = gk < length
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(p, v)
        m_sc[...] = m_new

    @pl.when(pi == npg - 1)
    def _write():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_paged(q1, k_pool, v_pool, block_tab, lengths, *, layer=0,
                       interpret=False):
    """Paged flash-decode over the serve pool layout (see repro.serve.cache).

    q1 [B, H, hd]; k_pool/v_pool [groups, num_pages+1, page_size, KV, hd]
    (last page = trash); block_tab [B, pages_per_slot] int32, -1 = unmapped;
    lengths scalar or [B] int32 (tokens live per row); layer = the group
    index to read (scalar, may be traced). Returns [B, H, hd]; rows with
    length == 0 return zeros.

    The walk is fused: block_tab/lengths/layer ride as scalar-prefetch
    operands and the pool BlockSpec index map resolves the physical page per
    (row, kv_head, logical_page) grid cell, so nothing gathers the pool into
    a contiguous [B, S, KV, hd] view.
    """
    B, H, hd = q1.shape
    groups, P1, ps, KV, _ = k_pool.shape
    trash = P1 - 1
    npg = block_tab.shape[1]
    G = H // KV
    qg = q1.reshape(B, KV, G, hd)
    tab = jnp.asarray(block_tab, jnp.int32)
    lens = _row_lengths(lengths, B)
    lay = jnp.asarray(layer, jnp.int32).reshape(1)

    def pool_map(b, h, pi, lay_ref, tab_ref, len_ref):
        t = tab_ref[b, pi]
        return (lay_ref[0], jnp.where(t >= 0, t, trash), 0, h, 0)

    def q_map(b, h, pi, *_):
        return (b, h, 0, 0)

    kernel = functools.partial(_paged_kernel, scale=hd ** -0.5, ps=ps,
                               npg=npg)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, KV, npg),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), q_map),
            pl.BlockSpec((1, 1, ps, 1, hd), pool_map),
            pl.BlockSpec((1, 1, ps, 1, hd), pool_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), q_map),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q1.dtype),
        interpret=interpret,
    )(lay, tab, lens, qg, k_pool, v_pool)
    return out.reshape(B, H, hd)
