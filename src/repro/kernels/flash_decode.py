"""Pallas TPU flash-decoding: one query token vs. a long KV cache.

Grid (b, kv_head, k_block), k_block innermost; the GQA group's G query rows
ride together as a [G, hd] tile (G <= 8 for the assigned archs — a VPU-sized
tile; the matmuls are [G,hd]x[hd,bk], MXU-aligned on bk and hd). Accumulators
(m, l, acc over G rows) persist in VMEM scratch; blocks beyond `length` (the
current cache fill) or outside the sliding window are skipped with pl.when —
decode cost scales with the live cache, not the allocated one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                   scale, window, bk, nk):
    ki = pl.program_id(2)
    k_start = ki * bk
    length = len_ref[0]

    @pl.when(ki == 0)
    def _reset():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    live = k_start < length
    if window > 0:
        live = jnp.logical_and(live, k_start + bk > length - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        gk = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = gk < length
        if window > 0:
            mask &= gk >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(p, v)
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(q1, k, v, length, *, window=0, block_k=256,
                 interpret=False):
    """q1 [B,H,hd]; k,v [B,KV,S,hd]; length scalar int32 (tokens live in
    cache). Returns [B,H,hd]."""
    B, H, hd = q1.shape
    KV, S = k.shape[1], k.shape[2]
    G = H // KV
    bk = min(block_k, S)
    while S % bk:
        bk -= 1
    nk = S // bk
    qg = q1.reshape(B, KV, G, hd)
    length = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=hd ** -0.5,
                               window=window, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q1.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(length, qg, k, v)
    return out.reshape(B, H, hd)
