"""Pallas TPU flash attention (forward), GQA + causal + sliding window.

Grid (b, kv_head, q_block, k_block) with the k_block axis innermost: TPU grids
execute sequentially per core, so the online-softmax accumulators (m, l, acc)
live in VMEM scratch across k_block steps and the output tile is written once
at the last k block. Causal / windowed tiles outside the band are skipped with
pl.when (zero compute on TPU, unlike the masked jnp path — this is the kernel's
FLOPs win over the XLA fallback).

BlockSpecs keep one (bq x hd) q tile, one (bk x hd) k/v tile, and the f32
accumulators resident in VMEM: bq=bk=128, hd<=256 => ~0.5 MB << 16 MB VMEM,
with MXU-aligned (128) matmul dims.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                 scale, causal, window, bq, bk, nk, gq0_last):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_start = qi * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _reset():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # band check: does this (q,k) tile intersect the causal/window band?
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window > 0:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        gq = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        gk = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= gq >= gk
        if window > 0:
            mask &= (gq - gk) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot(p, v)
        m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        block_q=128, block_k=128, interpret=False):
    """q [B,H,Sq,hd]; k,v [B,KV,Sk,hd] -> [B,H,Sq,hd]."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    while Sq % bq:
        bq -= 1
    bk = min(block_k, Sk)
    while Sk % bk:
        bk -= 1
    nq, nk = Sq // bq, Sk // bk

    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _attn_kernel, scale=hd ** -0.5, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, gq0_last=Sk - Sq)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
