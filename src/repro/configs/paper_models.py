"""The paper's own experiment models (Section 8.1): VGG-19 and ResNet-152,
as analytic layer-cost tables for the allocator/partitioner benchmarks
(batch 32, ImageNet 224x224, as in the paper)."""
from repro.models.cnn import vgg19_layer_costs, resnet152_layer_costs

PAPER_MODEL_COSTS = {
    "vgg19": vgg19_layer_costs,        # 548 MB params — comm-heavy DP
    "resnet152": resnet152_layer_costs,  # 230 MB params — compute-heavy
}
