"""chameleon-34b [vlm]: early-fusion, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The VQ-VAE image
frontend is a stub: input_specs() provides precomputed patch/token embeddings.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    attn_type="full",
    qk_norm=True,               # chameleon stabilizes with qk-norm
    mlp_type="swiglu",
    frontend="vlm_stub",
    stages=8, tp=2,             # 6 layers/stage; tp=2 for per-device weight fit
    num_microbatches=4,
    subquadratic=False,
)
