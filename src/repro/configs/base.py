"""Config system: architecture, shape, mesh, WSP and run configs.

Every assigned architecture is a frozen ``ArchConfig``; input-shape cells are
``ShapeConfig``s. The cross product (arch x shape) defines the dry-run cells.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assigned pool (exact public-literature config)."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads

    # --- attention pattern ---
    attn_type: str = "full"         # full | swa | local_global | none
    window_size: int = 0            # swa / local-layer window
    local_global_ratio: int = 0     # e.g. 5 -> 5 local : 1 global (gemma3)
    qk_norm: bool = False
    norm_style: str = "rms_pre"     # rms_pre | rms_sandwich | ln_pre
    mlp_type: str = "swiglu"        # swiglu | geglu | gelu | relu2
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # local_global: separate theta for global layers
    embed_scale: bool = False       # gemma-style sqrt(d) embedding scaling

    # --- SSM / hybrid ---
    ssm_type: str = ""              # "" | rwkv6 | ssd
    ssm_state: int = 0
    ssm_heads: int = 0              # 0 -> derived (d_inner // 64)
    ssm_expand: int = 2             # d_inner = ssm_expand * d_model (ssd)
    hybrid_parallel: bool = False   # hymba: attn + ssm branches in parallel

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- modality frontend ---
    frontend: str = "none"          # none | audio_stub | vlm_stub (input = embeddings)

    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- mesh mapping: model axis (16) = stages x tp ---
    stages: int = 16
    tp: int = 1
    # pipeline knobs
    num_microbatches: int = 4       # Nm (wave size); partitioner may lower it
    remat: bool = True              # recompute stage activations in backward
    # long_500k applicability (sub-quadratic attention available?)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    def check_production(self, model_axis: int = 16) -> None:
        assert self.stages * self.tp == model_axis, (
            f"{self.name}: stages*tp must equal the model-axis size {model_axis}")

    # ---- derived sizes -------------------------------------------------
    @property
    def layer_slots(self) -> int:
        """Per-stage layer slots (padded so every stage runs the same program)."""
        return math.ceil(self.num_layers / self.stages)

    @property
    def padded_layers(self) -> int:
        return self.layer_slots * self.stages

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return max(1, self.d_inner // 64)

    def layer_kinds(self) -> list[int]:
        """Per-layer attention kind: 0=full, 1=windowed, 2=none (pure ssm)."""
        kinds = []
        for i in range(self.padded_layers):
            if self.attn_type == "none":
                kinds.append(2)
            elif self.attn_type == "swa":
                kinds.append(1)
            elif self.attn_type == "local_global":
                r = self.local_global_ratio
                kinds.append(0 if (i % (r + 1)) == r else 1)
            elif self.attn_type == "hybrid_swa":
                # hymba: first, middle, last layers full; rest windowed
                full = {0, self.num_layers // 2, self.num_layers - 1}
                kinds.append(0 if i in full else 1)
            else:
                kinds.append(0)
        return kinds

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.head_dim
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size                  # head
        per_layer = 0
        if self.num_heads > 0:
            per_layer += d * self.num_heads * hd      # wq
            per_layer += 2 * d * self.num_kv_heads * hd
            per_layer += self.num_heads * hd * d      # wo
        if self.ssm_type == "ssd":
            di = self.d_inner
            per_layer += d * 2 * di + di * d          # in/out proj
            per_layer += di * 2 * self.ssm_state * 2  # B,C proj (approx)
        if self.ssm_type == "rwkv6":
            per_layer += 4 * d * d + 2 * d * 64       # r,k,v,o + decay lora
        if self.num_experts:
            gated = 2 if self.mlp_type in ("swiglu", "geglu") else 1
            per_layer += self.num_experts * (d * ff * gated + ff * d)
            per_layer += d * self.num_experts         # router
        else:
            gated = 2 if self.mlp_type in ("swiglu", "geglu") else 1
            per_layer += d * ff * gated + ff * d
        per_layer += 4 * d                            # norms
        return n + L * per_layer

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.num_experts:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        gated = 2 if self.mlp_type in ("swiglu", "geglu") else 1
        inactive = (self.num_experts - self.top_k) * (d * ff * gated + ff * d)
        return self.param_count() - L * inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class WSPConfig:
    """Wave Synchronous Parallel knobs (paper Sections 4-5)."""

    staleness_D: int = 0            # global clock-distance bound
    schedule: str = "gpipe"         # gpipe (wave-flush) | 1f1b (continuous injection)
    sync_mode: str = "allreduce"    # allreduce (SPMD D=0) | ps (host-level, D>=0)
    hierarchical: bool = True       # pod-local reduce before cross-pod
    compression: str = "none"       # none | topk
    compression_ratio: float = 0.01
    zero1: bool = False             # shard optimizer state over data axis


@dataclass(frozen=True)
class RunConfig:
    arch: "ArchConfig"
    shape: "ShapeConfig"
    wsp: WSPConfig = field(default_factory=WSPConfig)
    multi_pod: bool = False
    optimizer: str = "adamw"
    lr: float = 3e-4
    weight_decay: float = 0.1
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    cache_dtype: str = ""           # "" -> compute_dtype; "f8" halves KV traffic
    # which implementation runs the hot-path attention/SSM mixes:
    # "ref" = pure-jnp, "interpret"/"tpu" = the repro.kernels Pallas kernels
    # (interpret mode executes the kernel bodies in Python — CI parity)
    kernel_backend: str = "ref"
    # software-pipelined (skewed) schedule: issue the boundary-activation
    # ppermute of tick t concurrently with tick t+1's stage compute
    overlap: bool = False
    seed: int = 0
    loss_chunk: int = 512           # vocab-chunked CE chunk along seq

    @property
    def num_vw(self) -> int:
        return 16 * (2 if self.multi_pod else 1)

    @property
    def vw_batch(self) -> int:
        assert self.shape.global_batch % 16 == 0 or self.shape.global_batch == 1
        return max(1, self.shape.global_batch // 16)


def reduced(arch: ArchConfig, **over) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=4, d_model=64, d_ff=128, vocab_size=256,
        num_heads=max(0, min(arch.num_heads, 4)),
        num_kv_heads=max(0, min(arch.num_kv_heads, 2)),
        head_dim=16 if arch.num_heads else 0,
        stages=2, tp=1, num_microbatches=2,
        window_size=min(arch.window_size, 32) if arch.window_size else 0,
        ssm_state=min(arch.ssm_state, 8) if arch.ssm_state else 0,
        ssm_heads=2 if arch.ssm_type else 0,
        num_experts=min(arch.num_experts, 4) if arch.num_experts else 0,
        top_k=min(arch.top_k, 2) if arch.top_k else 0,
    )
    small.update(over)
    return dataclasses.replace(arch, **small)
