"""musicgen-medium [audio]: decoder-only over EnCodec tokens [arXiv:2306.05284].

48L d_model=1536 24H (MHA: kv=24) d_ff=6144 vocab=2048. The EnCodec/codebook
frontend is a stub: input_specs() provides precomputed frame embeddings.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    attn_type="full",
    norm_style="ln_pre",
    mlp_type="gelu",
    frontend="audio_stub",
    stages=16, tp=1,            # 3 layers/stage, no padding
    num_microbatches=8,
    subquadratic=False,
)
