"""granite-moe-3b-a800m [moe] [hf:ibm-granite/granite-3.0-3b-a800m-base].

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, 40 experts top-8.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    attn_type="full",
    mlp_type="swiglu",
    num_experts=40,
    top_k=8,
    tie_embeddings=True,
    stages=16, tp=1,            # 2 layers/stage
    num_microbatches=8,
    subquadratic=False,
)
