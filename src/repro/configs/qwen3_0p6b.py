"""qwen3-0.6b [dense]: qk_norm, GQA [hf:Qwen/Qwen3-0.6B family].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="qwen3-0.6b",
    family="dense",
    num_layers=28,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,               # qwen3 uses head_dim 128 (16*128 != d_model; q/o proj rectangular)
    d_ff=3072,
    vocab_size=151936,
    attn_type="full",
    qk_norm=True,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    stages=4, tp=4,             # 7 layers/stage, heads 4/dev, kv 2/dev
    num_microbatches=16,  # §Perf: nm16 cuts bubble 1.375->1.19
    subquadratic=False,
)
