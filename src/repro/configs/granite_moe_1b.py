"""granite-moe-1b-a400m [moe] [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155, 32 experts top-8.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    attn_type="full",
    mlp_type="swiglu",
    num_experts=32,
    top_k=8,
    tie_embeddings=True,
    stages=8, tp=2,             # 3 layers/stage; optional EP over tp
    num_microbatches=8,
    subquadratic=False,
)
