"""h2o-danube-1.8b [dense]: llama+mistral mix with SWA [arXiv:2401.16818].

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, sliding window 4096.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6912,
    vocab_size=32000,
    attn_type="swa",
    window_size=4096,
    mlp_type="swiglu",
    stages=8, tp=2,             # 3 layers/stage
    num_microbatches=8,
    subquadratic=True,          # SWA window bounds the KV working set
)
