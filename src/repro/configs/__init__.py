"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import (
    ArchConfig, ShapeConfig, WSPConfig, RunConfig, SHAPES, reduced,
)
from repro.configs.musicgen_medium import ARCH as MUSICGEN_MEDIUM
from repro.configs.hymba_1p5b import ARCH as HYMBA_1P5B
from repro.configs.qwen3_0p6b import ARCH as QWEN3_0P6B
from repro.configs.gemma3_1b import ARCH as GEMMA3_1B
from repro.configs.minitron_8b import ARCH as MINITRON_8B
from repro.configs.h2o_danube_1p8b import ARCH as H2O_DANUBE_1P8B
from repro.configs.rwkv6_3b import ARCH as RWKV6_3B
from repro.configs.chameleon_34b import ARCH as CHAMELEON_34B
from repro.configs.granite_moe_1b import ARCH as GRANITE_MOE_1B
from repro.configs.granite_moe_3b import ARCH as GRANITE_MOE_3B

ARCHS: dict[str, ArchConfig] = {
    a.name: a for a in [
        MUSICGEN_MEDIUM, HYMBA_1P5B, QWEN3_0P6B, GEMMA3_1B, MINITRON_8B,
        H2O_DANUBE_1P8B, RWKV6_3B, CHAMELEON_34B, GRANITE_MOE_1B,
        GRANITE_MOE_3B,
    ]
}

# Cells skipped per the assignment: long_500k needs sub-quadratic attention.
def cell_is_runnable(arch: ArchConfig, shape_name: str) -> bool:
    if shape_name == "long_500k" and not arch.subquadratic:
        return False
    return True


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def all_cells() -> list[tuple[str, str]]:
    """All 40 (arch x shape) cells; runnability flag applied by callers."""
    return [(a, s) for a in ARCHS for s in SHAPES]
