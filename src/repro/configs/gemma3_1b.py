"""gemma3-1b [dense]: 5:1 local:global attention [hf:google/gemma-3-1b-pt].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144. Local window 512,
local rope theta 10k, global theta 1M. Sandwich norms, GeGLU, embed scaling.
26 layers -> 4 stages x 7 slots = 28 (2 masked pad slots; see DESIGN.md).
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    attn_type="local_global",
    window_size=512,
    local_global_ratio=5,
    qk_norm=True,
    norm_style="rms_sandwich",
    mlp_type="geglu",
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    embed_scale=True,
    tie_embeddings=True,
    stages=4, tp=4,             # 4 q heads -> 1/dev; kv head replicated over tp
    num_microbatches=8,
    subquadratic=True,          # 5/6 layers windowed; global-layer KV seq-sharded at 500k
)
