"""minitron-8b [dense]: pruned nemotron [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000. Squared-ReLU MLP
(non-gated), as in the Nemotron-4 family.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    attn_type="full",
    mlp_type="relu2",
    stages=8, tp=2,             # 4 layers/stage; tp=2 halves per-device weights
    num_microbatches=16,  # §Perf: 1.84x vs nm4, temp 63->20GB
    subquadratic=False,
)
