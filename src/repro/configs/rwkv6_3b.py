"""rwkv6-3b [ssm]: Finch, data-dependent decay [arXiv:2404.05892].

32L d_model=2560 (attention-free) d_ff=8960 vocab=65536. 40 wkv heads x 64.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    attn_type="none",
    mlp_type="relu2",           # rwkv channel-mix uses squared relu
    ssm_type="rwkv6",
    ssm_state=64,               # head_dim of the wkv state (64x64 per head)
    ssm_heads=40,
    stages=16, tp=1,            # 2 layers/stage
    num_microbatches=8,
    subquadratic=True,          # O(1) recurrent state
)
