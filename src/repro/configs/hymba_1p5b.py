"""hymba-1.5b [hybrid]: parallel attention + mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Meta-tokens are stubbed off (systems-level reproduction; see DESIGN.md).
Most layers use SWA (window 1024); first/middle/last are global.
"""
from repro.configs.base import ArchConfig

ARCH = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_type="hybrid_swa",
    window_size=1024,
    mlp_type="swiglu",
    ssm_type="ssd",
    ssm_state=16,
    ssm_expand=2,
    hybrid_parallel=True,
    stages=16, tp=1,            # 2 layers/stage
    num_microbatches=8,
    subquadratic=True,
)
