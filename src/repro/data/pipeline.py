"""Deterministic, shardable, resumable data pipeline.

Synthetic LM streams with learnable structure (order-2 Markov chains with a
seeded transition table) so convergence experiments have signal, plus a
memory-mapped token-shard reader for real corpora. Iterator state (epoch,
cursor) is part of the checkpoint, so restart is exact.
"""
from __future__ import annotations

import os
from dataclasses import dataclass

import numpy as np


class MarkovLM:
    """Order-2 Markov source: next ~ Cat(T[a, b]). Deterministic from seed."""

    def __init__(self, vocab: int, seed: int = 0, concentration: float = 0.3):
        rng = np.random.default_rng(seed)
        v = min(vocab, 64)                       # latent alphabet
        self.vocab = vocab
        self.v = v
        logits = rng.gumbel(size=(v, v, v)) / concentration
        self.T = np.exp(logits - logits.max(-1, keepdims=True))
        self.T /= self.T.sum(-1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int, seq: int):
        out = np.zeros((batch, seq + 1), np.int64)
        out[:, 0] = rng.integers(0, self.v, batch)
        out[:, 1] = rng.integers(0, self.v, batch)
        for t in range(2, seq + 1):
            p = self.T[out[:, t - 2], out[:, t - 1]]
            cum = np.cumsum(p, -1)
            u = rng.random((batch, 1))
            out[:, t] = (u > cum).sum(-1)
        return out[:, :-1].astype(np.int32), out[:, 1:].astype(np.int32)


class MMapTokens:
    """Flat token file (np.int32) read as contiguous windows."""

    def __init__(self, path: str):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")

    def window(self, start: int, batch: int, seq: int):
        n = batch * (seq + 1)
        start = start % max(len(self.tokens) - n, 1)
        w = np.asarray(self.tokens[start:start + n]).reshape(batch, seq + 1)
        return w[:, :-1].copy(), w[:, 1:].copy()


@dataclass
class LoaderState:
    step: int = 0
    epoch: int = 0


class ShardedLoader:
    """Per-virtual-worker stream: worker `shard` of `num_shards` sees a
    disjoint deterministic substream. Resumable via state_dict."""

    def __init__(self, source, batch: int, seq: int, shard: int,
                 num_shards: int, seed: int = 0):
        self.source = source
        self.batch, self.seq = batch, seq
        self.shard, self.num_shards = shard, num_shards
        self.seed = seed
        self.state = LoaderState()

    def next(self):
        s = self.state
        if isinstance(self.source, MarkovLM):
            rng = np.random.default_rng(
                (self.seed, self.shard, s.epoch, s.step))
            x, y = self.source.sample(rng, self.batch, self.seq)
        else:
            stride = self.batch * (self.seq + 1)
            start = (s.step * self.num_shards + self.shard) * stride
            x, y = self.source.window(start, self.batch, self.seq)
        s.step += 1
        return x, y

    def state_dict(self):
        return {"step": self.state.step, "epoch": self.state.epoch}

    def load_state_dict(self, sd):
        self.state = LoaderState(**sd)


def write_token_file(path: str, tokens: np.ndarray):
    tokens.astype(np.int32).tofile(path)
    return path
