"""Shims over the jax API surface this repo targets.

The code is written against the current jax API (jax.shard_map with
check_vma, jax.set_mesh, jax.sharding.AxisType); older jaxlibs (0.4.x, the
pinned CI/container version) expose the same functionality under previous
names. Import shard_map/set_mesh from here instead of jax directly.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _shard_map_04(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=check_vma, **kw)

if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    # a Mesh is itself a context manager on 0.4.x
    def set_mesh(mesh):
        return mesh
