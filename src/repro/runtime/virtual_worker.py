"""A virtual worker: the paper's group of k GPUs running PMP, driven as a
thread against the parameter server with WSP gating.

On real hardware each VW runs the jitted pipelined wave step on its mesh
slice; here the wave step is any callable (the single-device oracle on CPU,
the shard_map pipeline on a fake mesh) — the WSP protocol is identical.
Heterogeneity is simulated with per-VW speed factors / straggle schedules.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np


@dataclass
class VWMetrics:
    losses: list = field(default_factory=list)
    wave_times: list = field(default_factory=list)
    wall_clock: list = field(default_factory=list)
    waves: int = 0


class VirtualWorker(threading.Thread):
    def __init__(self, wid: str, ps, wave_step: Callable, loader, opt_state,
                 *, max_waves: int, pull_every: int = 1,
                 slowdown: float = 0.0,
                 straggle_fn: Optional[Callable[[int], float]] = None,
                 stop_event: Optional[threading.Event] = None,
                 fail_at_wave: Optional[int] = None):
        super().__init__(daemon=True, name=wid)
        self.wid, self.ps, self.wave_step = wid, ps, wave_step
        self.loader, self.opt_state = loader, opt_state
        self.max_waves, self.pull_every = max_waves, pull_every
        self.slowdown, self.straggle_fn = slowdown, straggle_fn
        self.stop_event = stop_event or threading.Event()
        self.fail_at_wave = fail_at_wave
        self.metrics = VWMetrics()
        self.failed = False
        self.params = None

    def run(self):
        t_start = time.monotonic()
        self.ps.register(self.wid)
        self.params = self.ps.pull(self.wid)
        wave = self.ps.clock.local_clock(self.wid)
        try:
            while wave < self.max_waves and not self.stop_event.is_set():
                if self.fail_at_wave is not None and wave == self.fail_at_wave:
                    self.failed = True
                    self.ps.deregister(self.wid)      # simulated node failure
                    return
                if not self.ps.wait_pull_allowed(self.wid, timeout=120.0):
                    break
                t0 = time.monotonic()
                x, y = self.loader.next()
                deltas, self.opt_state, loss = self.wave_step(
                    self.params, self.opt_state, x, y)
                loss = float(loss)
                extra = self.slowdown
                if self.straggle_fn is not None:
                    extra += self.straggle_fn(wave)
                if extra > 0:
                    time.sleep(extra)
                wave = self.ps.push_wave(self.wid, deltas)
                # local weights see their own wave immediately (paper Sec. 4)
                self.params = jax.tree.map(np.add, self.params,
                                           jax.tree.map(np.asarray, deltas))
                if self.pull_every and wave % self.pull_every == 0:
                    self.params = self.ps.pull(self.wid)
                self.metrics.losses.append(loss)
                self.metrics.wave_times.append(time.monotonic() - t0)
                self.metrics.wall_clock.append(time.monotonic() - t_start)
                self.metrics.waves = wave
        except Exception:
            self.failed = True
            raise
