"""A virtual worker: the paper's group of k GPUs running PMP, driven as a
thread against the parameter server with WSP gating.

On real hardware each VW runs the jitted pipelined wave step on its mesh
slice; here the wave step is any callable (the single-device oracle on CPU,
the shard_map pipeline on a fake mesh) — the WSP protocol is identical.
Heterogeneity is simulated with per-VW speed factors / straggle schedules.

With async_push=True the VW overlaps its wave-aggregated push with the next
wave's compute (paper Section 5 / XPipe-style weight handling): the delta is
handed to a per-worker outbox thread which pays the transport delay, applies
the update, and advances the WSP clock when the push *lands*. The VW starts
the next wave's forward immediately on its locally-updated weights, gating
each wave at its logical clock (at_clock) so overlap never buys extra
staleness, and waiting for the in-flight push before the next push (ordering)
or any pull (a pull must see the worker's own landed wave).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.obs import NULL_TRACER, emit_pipeline_ticks
from repro.obs.metrics import INT_BOUNDS, SECONDS_BOUNDS


@dataclass
class VWMetrics:
    losses: list = field(default_factory=list)
    wave_times: list = field(default_factory=list)
    wall_clock: list = field(default_factory=list)
    waves: int = 0
    overlap_seconds: float = 0.0    # in-flight push time hidden under compute
    push_wait_seconds: float = 0.0  # time blocked on an in-flight push
    gate_timeouts: int = 0          # staleness gates that timed out


class _PushHandle:
    __slots__ = ("event", "clock", "enqueued_at", "landed_at", "exc")

    def __init__(self):
        self.event = threading.Event()
        self.clock = None
        self.enqueued_at = time.monotonic()
        self.landed_at = None
        self.exc = None


class _Outbox(threading.Thread):
    """Per-worker background pusher: drains queued deltas into the PS in
    FIFO order, paying the transport delay off the worker's critical path."""

    def __init__(self, wid: str, ps, tracer=NULL_TRACER):
        super().__init__(daemon=True, name=f"{wid}-outbox")
        self.wid, self.ps, self.tracer = wid, ps, tracer
        self._q: queue.Queue = queue.Queue()

    def submit(self, deltas) -> _PushHandle:
        h = _PushHandle()
        self._q.put((deltas, h))
        return h

    def close(self):
        self._q.put(None)

    def run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            deltas, h = item
            # the push span covers the in-flight segment: transport delay +
            # apply + clock advance, on the worker's outbox track
            with self.tracer.span(f"{self.wid}/outbox", "push"):
                try:
                    h.clock = self.ps.push_wave(self.wid, deltas)
                except Exception as e:      # surfaced at the next await
                    h.exc = e
            h.landed_at = time.monotonic()
            h.event.set()


class VirtualWorker(threading.Thread):
    def __init__(self, wid: str, ps, wave_step: Callable, loader, opt_state,
                 *, max_waves: int, pull_every: int = 1,
                 slowdown: float = 0.0,
                 straggle_fn: Optional[Callable[[int], float]] = None,
                 stop_event: Optional[threading.Event] = None,
                 fail_at_wave: Optional[int] = None,
                 async_push: bool = False,
                 tracer=None, D: Optional[int] = None, tick_plan=None,
                 injector=None, vw_index: Optional[int] = None,
                 crash_at: Optional[int] = None,
                 gate_timeout_s: float = 120.0):
        super().__init__(daemon=True, name=wid)
        self.wid, self.ps, self.wave_step = wid, ps, wave_step
        self.loader, self.opt_state = loader, opt_state
        self.max_waves, self.pull_every = max_waves, pull_every
        self.slowdown, self.straggle_fn = slowdown, straggle_fn
        self.stop_event = stop_event or threading.Event()
        self.fail_at_wave = fail_at_wave
        self.async_push = async_push
        # fault seam: crash_at kills the thread WITHOUT deregistering (an
        # injected WorkerCrash — the supervisor must notice and evict);
        # fail_at_wave stays the legacy *graceful* failure that says
        # goodbye. injector + vw_index drive slowdown-onset consults.
        self.injector = injector
        self.vw_index = vw_index
        self.crash_at = crash_at
        self.gate_timeout_s = gate_timeout_s
        # observability: D is the Plan's staleness bound (audited per wave),
        # tick_plan the (schedule, ticks) modeled pipeline rendered under
        # each wave span (core.wave.tick_schedule output)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.audit_D = D
        self.tick_plan = tick_plan
        self.metrics = VWMetrics()
        self.failed = False
        self.done = False               # completed its waves normally
        self.crashed = False            # died without deregistering
        self.evicted = False            # supervisor pulled us from the clock
        self.error = None               # the FaultError that took us down
        self.params = None
        self._outbox: Optional[_Outbox] = None
        self._inflight: Optional[_PushHandle] = None

    def evict(self):
        """Called by the FleetSupervisor (before it deregisters us): the
        worker exits cleanly at its next gate instead of training on."""
        self.evicted = True

    def _await_inflight(self, timeout: float = 120.0, compute_span=None):
        """Block until the in-flight push (if any) has landed. `compute_span`
        is the [start, end) wall interval of the wave's work (loader +
        wave step + simulated slowdown) that ran while the push was in
        flight; only the flight time inside that interval is credited as
        overlap — time blocked at the WSP gate saved no wall clock and is
        already visible in wait_seconds."""
        h, self._inflight = self._inflight, None
        if h is None:
            return
        t_wait = time.monotonic()
        if not h.event.wait(timeout):
            raise TimeoutError(f"{self.wid}: async push did not land")
        if h.exc is not None:
            raise h.exc
        now = time.monotonic()
        self.metrics.push_wait_seconds += now - t_wait
        if compute_span is not None:
            c0, c1 = compute_span
            self.metrics.overlap_seconds += max(
                0.0, min(h.landed_at, c1) - max(h.enqueued_at, c0))

    def run(self):
        t_start = time.monotonic()
        tr = self.tracer
        self.ps.register(self.wid)
        self.params = self.ps.pull(self.wid)
        wave = self.ps.clock.local_clock(self.wid)
        if self.async_push:
            self._outbox = _Outbox(self.wid, self.ps, tracer=tr)
            self._outbox.start()
        try:
            while wave < self.max_waves and not self.stop_event.is_set():
                if self.fail_at_wave is not None and wave == self.fail_at_wave:
                    self.failed = True
                    self._await_inflight()
                    self.ps.deregister(self.wid)      # simulated node failure
                    return
                if self.crash_at is not None and wave == self.crash_at:
                    # injected WorkerCrash: the node vanishes — no goodbye,
                    # no deregister, and any in-flight push is left to land
                    # (or not) on its own. Detection is the supervisor's job.
                    self.failed = self.crashed = True
                    tr.instant(self.wid, "crash", wave=wave)
                    tr.metrics.counter_inc("fault/crashes")
                    return
                # gate at the logical clock: `wave` counts enqueued pushes,
                # so the staleness predicate matches the blocking runtime
                # even while a push is still in flight
                tg = tr.now()
                if not self.ps.gate(self.wid, timeout=self.gate_timeout_s,
                                    at_clock=wave):
                    # deregistered while waiting: the supervisor evicted us
                    self.evicted = True
                    tr.instant(self.wid, "evicted_exit", wave=wave)
                    return
                tg1 = tr.now()
                if tg1 - tg > 1e-4:     # only waits, not instant passes
                    tr.add_span(self.wid, "gate_wait", tg, tg1, wave=wave)
                tr.metrics.observe("train/wait_s", tg1 - tg,
                                   bounds=SECONDS_BOUNDS)
                # staleness this wave runs at: my clock minus the slowest
                # worker's. The gate just guaranteed stale <= D and the
                # global clock only grows, so any sample > D is a protocol
                # violation — this is the audit the summary CLI enforces.
                stale = wave - self.ps.clock.global_clock()
                tr.metrics.observe("wsp/staleness", float(stale),
                                   bounds=INT_BOUNDS)
                tr.counter(self.wid, "staleness", stale)
                if self.audit_D is not None and stale > self.audit_D:
                    tr.instant(self.wid, "staleness_violation",
                               wave=wave, stale=stale, D=self.audit_D)
                    tr.metrics.counter_inc("wsp/staleness_violations")
                t0 = time.monotonic()
                with tr.span(self.wid, "wave", wave=wave):
                    x, y = self.loader.next()
                    deltas, self.opt_state, loss = self.wave_step(
                        self.params, self.opt_state, x, y)
                    loss = float(loss)
                    extra = self.slowdown
                    if self.straggle_fn is not None:
                        extra += self.straggle_fn(wave)
                    if self.injector is not None and self.vw_index is not None:
                        extra += self.injector.slowdown_extra(
                            self.vw_index, wave)
                    if extra > 0:
                        time.sleep(extra)
                if self.tick_plan is not None and tr.enabled:
                    # render the modeled intra-VW pipeline (stages ×
                    # microbatch ticks) scaled into the measured wave window
                    sched, ticks = self.tick_plan
                    emit_pipeline_ticks(tr, self.wid, sched, ticks,
                                        t0, time.monotonic())
                if self._outbox is not None:
                    # pushes land in order: wave w-1 must be applied before
                    # wave w's transfer may complete
                    self._await_inflight(compute_span=(t0, time.monotonic()))
                    self._inflight = self._outbox.submit(deltas)
                    tr.instant(self.wid, "push_enqueue", wave=wave)
                    wave += 1
                else:
                    with tr.span(self.wid, "push", wave=wave):
                        wave = self.ps.push_wave(self.wid, deltas)
                tr.counter(self.wid, "clock", wave)
                # local weights see their own wave immediately (paper Sec. 4)
                # — unless the pull below replaces them wholesale anyway
                if self.pull_every != 1:
                    self.params = jax.tree.map(np.add, self.params,
                                               jax.tree.map(np.asarray,
                                                            deltas))
                if self.pull_every and wave % self.pull_every == 0:
                    # a pull must include this worker's own landed wave
                    with tr.span(self.wid, "pull", wave=wave):
                        self._await_inflight()
                        self.params = self.ps.pull(self.wid)
                self.metrics.losses.append(loss)
                self.metrics.wave_times.append(time.monotonic() - t0)
                self.metrics.wall_clock.append(time.monotonic() - t_start)
                self.metrics.waves = wave
            self._await_inflight()
            self.done = True
        except Exception as e:
            from repro.faults.errors import FaultError, GateTimeout
            self.failed = True
            if isinstance(e, FaultError):
                # typed fault: record it, say goodbye, exit without killing
                # the thread's stack trace budget — the Engine surfaces it
                # via TrainReport counters / DegradedRunError
                self.error = e
                if isinstance(e, GateTimeout):
                    self.metrics.gate_timeouts += 1
                    tr.instant(self.wid, "gate_timeout", wave=e.wave)
                    tr.metrics.counter_inc("fault/gate_timeouts")
                else:
                    tr.instant(self.wid, "fault_crash", error=repr(e))
                    tr.metrics.counter_inc("fault/crashes")
                self.ps.deregister(self.wid)
                return
            raise
        finally:
            if self._outbox is not None:
                self._outbox.close()
                self._outbox.join(timeout=10.0)
