"""Atomic, exact checkpointing of the full training state.

Saved per checkpoint: global weights (PS state), WSP clocks, per-VW optimizer
state, data-loader cursors, and run metadata. Files are written to a temp dir
and renamed atomically; restore is bitwise-exact (tested).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten_named(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(template, named):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = named[key]
        leaves.append(np.asarray(arr).reshape(np.shape(leaf)).astype(
            np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, trees: dict, meta: dict):
    """trees: name -> pytree (params, opt_states, ...); meta: JSON-able."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        for name, tree in trees.items():
            np.savez(os.path.join(tmp, f"{name}.npz"),
                     **_flatten_named(tree))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, **meta}, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_checkpoint(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return os.path.join(ckpt_dir, steps[-1]) if steps else None


def load_checkpoint(path: str, templates: dict):
    """templates: name -> pytree with target shapes/dtypes."""
    out = {}
    for name, template in templates.items():
        with np.load(os.path.join(path, f"{name}.npz")) as z:
            out[name] = _unflatten_like(template, dict(z))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return out, meta
