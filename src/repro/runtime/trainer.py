"""Deprecated constructors kept for source compatibility.

The host-level WSP runtime now lives behind the declarative experiment layer
(`repro.api`): describe a scenario with a `Plan` and run it with `Engine`.
`WSPTrainer` and `bsp_allreduce_baseline` survive only as thin shims that
build a Plan internally — new code should construct the Plan directly:

    from repro.api import Plan, ClusterSpec, RunSpec, WSP, BSP, Engine

    report = Engine(Plan(arch=cfg,
                         cluster=ClusterSpec(num_vw=4, topology="hetero"),
                         sync=WSP(D=2, async_push=True),
                         run=RunSpec(max_waves=50))).fit()

See the README's "Experiment API" migration table for the kwarg mapping.
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional

from repro.api.engine import Engine
from repro.api.plan import ClusterSpec, Plan, RunSpec
from repro.api.report import TrainReport                       # noqa: F401
from repro.api.sync import BSP, WSP
from repro.dist.topology import ClusterTopology


def _deprecated(old: str, new: str):
    warnings.warn(
        f"{old} is deprecated; build a repro.api.Plan and use {new} instead "
        f"(see README 'Experiment API')",
        DeprecationWarning, stacklevel=3)


class WSPTrainer:
    """Deprecated: shim over repro.api.Engine with a WSP SyncPolicy."""

    def __init__(self, init_params, wave_step: Callable, optimizer, *,
                 num_vw: int, D: int = 0, batch: int = 8, seq: int = 64,
                 vocab: int = 256, max_waves: int = 20,
                 speeds: Optional[list] = None,
                 straggle_fns: Optional[list] = None,
                 compression_ratio: Optional[float] = None,
                 codec=None,
                 topology: ClusterTopology | str | None = None,
                 time_scale: float = 1.0,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 fail_at: Optional[dict[int, int]] = None,
                 data_seed: int = 0, pull_every: int = 1,
                 async_push: bool = False):
        _deprecated("WSPTrainer", "Engine(plan).fit()")
        plan = Plan(
            cluster=ClusterSpec(num_vw=num_vw, topology=topology,
                                speeds=speeds, straggle_fns=straggle_fns,
                                fail_at=fail_at or {},
                                time_scale=time_scale),
            sync=WSP(D=D, pull_every=pull_every, async_push=async_push),
            run=RunSpec(max_waves=max_waves, batch=batch, seq=seq,
                        vocab=vocab, codec=codec,
                        compression_ratio=compression_ratio,
                        ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                        data_seed=data_seed))
        self.engine = Engine(plan, params=init_params, wave_step=wave_step,
                             optimizer=optimizer)
        # eager build, matching the old constructor's observable surface
        self.engine._ensure_model()
        self.engine._ensure_ps(plan.sync)

    @property
    def ps(self):
        return self.engine.ps

    @property
    def workers(self):
        return self.engine.workers

    @property
    def topology(self):
        return self.engine.topology

    @property
    def stop_event(self):
        return self.engine.stop_event

    def run(self, *, rejoin_failed_after: Optional[float] = None
            ) -> TrainReport:
        return self.engine.fit(rejoin_failed_after=rejoin_failed_after)


def bsp_allreduce_baseline(init_params, wave_step, optimizer, *, num_vw: int,
                           batch: int, seq: int, vocab: int, max_waves: int,
                           speeds: Optional[list] = None,
                           topology: ClusterTopology | str | None = None,
                           data_seed: int = 0) -> TrainReport:
    """Deprecated: shim over repro.api.Engine with the BSP SyncPolicy."""
    _deprecated("bsp_allreduce_baseline", "Engine(plan with sync=BSP()).fit()")
    plan = Plan(
        cluster=ClusterSpec(num_vw=num_vw, topology=topology, speeds=speeds),
        sync=BSP(),
        run=RunSpec(max_waves=max_waves, batch=batch, seq=seq, vocab=vocab,
                    data_seed=data_seed))
    return Engine(plan, params=init_params, wave_step=wave_step,
                  optimizer=optimizer).fit()
