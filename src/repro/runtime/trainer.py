"""Multi-virtual-worker WSP trainer: the host-level HetPipe runtime.

Spawns N VirtualWorker threads against a sharded ParameterServer, with
simulated heterogeneous speeds / stragglers, periodic checkpointing, elastic
worker removal & re-join, and an AllReduce-BSP baseline ("Horovod" analogue)
for the paper's comparison experiments.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.param_server import ParameterServer
from repro.data.pipeline import MarkovLM, ShardedLoader
from repro.dist import collectives
from repro.dist.topology import ClusterTopology, make_topology
from repro.dist.transport import SimulatedTransport
from repro.runtime.checkpoint import save_checkpoint, load_checkpoint
from repro.runtime.virtual_worker import VirtualWorker


@dataclass
class TrainReport:
    losses: list = field(default_factory=list)      # (wall_s, wid, loss)
    waves: int = 0
    wall_s: float = 0.0
    wait_seconds: dict = field(default_factory=dict)
    bytes_pushed: int = 0
    bytes_wire: int = 0
    comm_seconds: float = 0.0                       # modeled network time
    overlap_seconds: float = 0.0                    # comm hidden under compute
    push_wait_seconds: float = 0.0                  # comm NOT hidden (blocked)
    comm: dict = field(default_factory=dict)        # transport link stats

    def loss_curve(self):
        pts = sorted(self.losses)
        return (np.array([p[0] for p in pts]),
                np.array([p[2] for p in pts]))


class WSPTrainer:
    def __init__(self, init_params, wave_step: Callable, optimizer, *,
                 num_vw: int, D: int = 0, batch: int = 8, seq: int = 64,
                 vocab: int = 256, max_waves: int = 20,
                 speeds: Optional[list[float]] = None,
                 straggle_fns: Optional[list] = None,
                 compression_ratio: Optional[float] = None,
                 codec=None,
                 topology: ClusterTopology | str | None = None,
                 time_scale: float = 1.0,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 fail_at: Optional[dict[int, int]] = None,
                 data_seed: int = 0, pull_every: int = 1,
                 async_push: bool = False):
        if isinstance(topology, str):
            topology = make_topology(topology, num_vw)
        self.topology = topology
        transport = (SimulatedTransport(topology, time_scale=time_scale)
                     if topology is not None else None)
        self.ps = ParameterServer(init_params, D=D,
                                  compression_ratio=compression_ratio,
                                  codec=codec, transport=transport)
        self.wave_step, self.optimizer = wave_step, optimizer
        self.num_vw, self.max_waves = num_vw, max_waves
        self.batch, self.seq = batch, seq
        self.speeds = speeds or [0.0] * num_vw
        self.straggle_fns = straggle_fns or [None] * num_vw
        self.source = MarkovLM(vocab, seed=data_seed)
        self.ckpt_dir, self.ckpt_every = ckpt_dir, ckpt_every
        self.fail_at = fail_at or {}
        self.pull_every = pull_every
        self.async_push = async_push
        self.stop_event = threading.Event()
        self.workers: dict[str, VirtualWorker] = {}

    def _make_worker(self, i: int, wid: str) -> VirtualWorker:
        loader = ShardedLoader(self.source, self.batch, self.seq, i,
                               self.num_vw, seed=17)
        return VirtualWorker(
            wid, self.ps, self.wave_step, loader,
            self.optimizer.init(self.ps.pull()),
            max_waves=self.max_waves, pull_every=self.pull_every,
            slowdown=self.speeds[i],
            straggle_fn=self.straggle_fns[i],
            stop_event=self.stop_event,
            fail_at_wave=self.fail_at.get(i),
            async_push=self.async_push)

    def run(self, *, rejoin_failed_after: Optional[float] = None
            ) -> TrainReport:
        t0 = time.monotonic()
        for i in range(self.num_vw):
            wid = f"vw{i}"
            self.workers[wid] = self._make_worker(i, wid)
            self.workers[wid].start()
        ckpt_step = 0
        rejoined = set()
        periodic = bool(self.ckpt_dir and self.ckpt_every) \
            or rejoin_failed_after is not None
        if not periodic:
            # nothing to supervise: block on the (fixed) worker set directly
            for w in list(self.workers.values()):
                w.join()
        while periodic and any(w.is_alive() for w in self.workers.values()):
            # wake on wave completion / worker exit rather than busy-polling
            self.ps.push_event.wait(timeout=0.25)
            self.ps.push_event.clear()
            # elastic re-join of failed workers
            if rejoin_failed_after is not None:
                for wid, w in list(self.workers.items()):
                    if (w.failed and not w.is_alive() and wid not in rejoined
                            and time.monotonic() - t0 > rejoin_failed_after):
                        rejoined.add(wid)
                        i = int(wid[2:])
                        if (self.topology is not None
                                and f"vw{i}" in self.topology.pod_of):
                            # the re-joined worker lives on the failed one's
                            # node as far as the network model is concerned
                            self.topology.add_alias(wid + "r", f"vw{i}")
                        nw = self._make_worker(i, wid + "r")
                        nw.fail_at_wave = None
                        self.workers[wid + "r"] = nw
                        nw.start()
            # periodic checkpoint (PS + clocks)
            if self.ckpt_dir and self.ckpt_every:
                gc = self.ps.clock.global_clock()
                if gc >= ckpt_step + self.ckpt_every:
                    ckpt_step = gc
                    save_checkpoint(
                        self.ckpt_dir, gc,
                        {"params": self.ps.pull()},
                        {"clocks": dict(self.ps.clock.state.clocks),
                         "push_count": self.ps.push_count})
        report = TrainReport()
        for wid, w in self.workers.items():
            for t, l in zip(w.metrics.wall_clock, w.metrics.losses):
                report.losses.append((t, wid, l))
            report.waves += w.metrics.waves
            report.overlap_seconds += w.metrics.overlap_seconds
            report.push_wait_seconds += w.metrics.push_wait_seconds
        report.wall_s = time.monotonic() - t0
        report.wait_seconds = dict(self.ps.clock.wait_seconds)
        report.bytes_pushed = self.ps.bytes_pushed
        report.bytes_wire = self.ps.bytes_wire
        report.comm_seconds = self.ps.comm_seconds
        report.comm = self.ps.transport.stats()
        return report


def bsp_allreduce_baseline(init_params, wave_step, optimizer, *, num_vw: int,
                           batch: int, seq: int, vocab: int, max_waves: int,
                           speeds: Optional[list[float]] = None,
                           topology: ClusterTopology | str | None = None,
                           data_seed: int = 0) -> TrainReport:
    """Synchronous AllReduce DP (the paper's Horovod baseline): every wave,
    all VWs' deltas are reduced via an emulated ring all-reduce (averaged —
    each VW sees 1/N of the batch) and applied to one global copy.

    Wall clock is a *simulated* straggler-gated time: the VW steps actually
    run sequentially on this host, so each wave is charged the max over VWs
    of (measured compute + simulated slowdown) plus the topology-predicted
    all-reduce time, and all of a wave's losses share that one timestamp.
    """
    if isinstance(topology, str):
        topology = make_topology(topology, num_vw)
    names = [f"vw{i}" for i in range(num_vw)]
    source = MarkovLM(vocab, seed=data_seed)
    loaders = [ShardedLoader(source, batch, seq, i, num_vw, seed=17)
               for i in range(num_vw)]
    params = jax.tree.map(np.asarray, init_params)
    opt_states = [optimizer.init(init_params) for _ in range(num_vw)]
    speeds = speeds or [0.0] * num_vw
    report = TrainReport()
    sim_t = 0.0
    for wave in range(max_waves):
        deltas_all, losses = [], []
        t_wave = 0.0
        for i in range(num_vw):
            x, y = loaders[i].next()
            tw0 = time.monotonic()
            deltas, opt_states[i], loss = wave_step(params, opt_states[i],
                                                    x, y)
            t_wave = max(t_wave, time.monotonic() - tw0 + speeds[i])
            deltas_all.append(deltas)
            losses.append(float(loss))
        mean_delta, coll_s = collectives.ring_allreduce(
            deltas_all, topology=topology, workers=names, average=True)
        params = jax.tree.map(np.add, params, mean_delta)
        nbytes = sum(np.asarray(l).nbytes
                     for l in jax.tree.leaves(mean_delta))
        report.bytes_pushed += nbytes * num_vw
        # ring wire traffic: each VW moves 2(N-1)/N of the vector per wave
        report.bytes_wire += int(2 * (num_vw - 1) * nbytes) \
            if num_vw > 1 else 0
        report.comm_seconds += coll_s
        sim_t += t_wave + coll_s
        for i, l in enumerate(losses):
            report.losses.append((sim_t, f"vw{i}", l))
        report.waves += num_vw
    report.wall_s = sim_t
    return report
