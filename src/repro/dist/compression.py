"""Gradient codecs for the wave-delta push path (paper Section 5 variant).

Two codecs, both operating on flat float32 vectors (one per PS leaf):

  top-k + error feedback — send the k largest-magnitude entries, accumulate
    the rest into a per-key residual that is re-injected next wave. The
    residual makes the scheme mass-conserving: over any horizon,
    sum(sent) + residual == sum(true gradients) exactly.
  int8 stochastic rounding — dense 1 byte/entry with an unbiased rounding
    rule (E[q * scale] == x), the classic low-precision DP codec.

The compressor API is (idx, vals) pairs so the parameter server can apply
sparse updates in place: flat[idx] += vals.
"""
from __future__ import annotations

import threading

import numpy as np


# -- top-k sparsification -------------------------------------------------

def topk_compress(flat: np.ndarray, ratio: float):
    """Keep the ceil(ratio * n) largest-|x| entries of a flat vector.

    Returns (idx, vals) with idx sorted ascending (deterministic given the
    input; ties broken by argpartition order).
    """
    flat = np.asarray(flat, np.float32).ravel()
    n = flat.size
    k = max(1, min(n, int(round(ratio * n))))
    if k >= n:
        idx = np.arange(n, dtype=np.int64)
        return idx, flat.copy()
    idx = np.argpartition(np.abs(flat), n - k)[n - k:]
    idx = np.sort(idx).astype(np.int64)
    return idx, flat[idx].copy()


def topk_decompress(idx: np.ndarray, vals: np.ndarray, n: int) -> np.ndarray:
    out = np.zeros(n, np.float32)
    out[np.asarray(idx, np.int64)] = np.asarray(vals, np.float32)
    return out


class ErrorFeedbackCompressor:
    """Top-k with per-key residual accumulation (error feedback / EF-SGD).

    Keys are caller-chosen (the PS uses "{worker}/{leaf}") so each worker x
    leaf stream keeps its own residual and compression is stateless across
    streams.
    """

    def __init__(self, ratio: float):
        assert 0.0 < ratio <= 1.0, ratio
        self.ratio = float(ratio)
        self._residual: dict[str, np.ndarray] = {}

    def compress(self, key: str, flat: np.ndarray):
        flat = np.asarray(flat, np.float32).ravel()
        resid = self._residual.get(key)
        if resid is None or resid.size != flat.size:
            resid = np.zeros(flat.size, np.float32)
        acc = flat + resid
        idx, vals = topk_compress(acc, self.ratio)
        new_resid = acc.copy()
        new_resid[idx] = 0.0
        self._residual[key] = new_resid
        return idx, vals

    def wire_bytes(self, idx: np.ndarray, vals: np.ndarray) -> int:
        """int32 index + float32 value per kept entry."""
        return int(idx.size) * 4 + int(np.asarray(vals).nbytes)


# -- int8 stochastic rounding --------------------------------------------

class Int8StochasticQuantizer:
    """Dense int8 codec with unbiased stochastic rounding.

    q = floor(x / scale + u), u ~ U[0, 1), scale = max|x| / 127, so
    E[q * scale] = x. Decoded values are returned dense ((arange, vals))
    to satisfy the same apply-by-index contract as the sparse codec;
    wire_bytes charges 1 byte/entry + the float32 scale.
    """

    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)
        # np.random.Generator is not thread-safe; the PS calls compress from
        # every worker thread concurrently
        self._rng_lock = threading.Lock()

    def quantize(self, flat: np.ndarray):
        flat = np.asarray(flat, np.float32).ravel()
        amax = float(np.max(np.abs(flat))) if flat.size else 0.0
        if amax == 0.0:
            return np.zeros(flat.size, np.int8), 0.0
        scale = amax / 127.0
        with self._rng_lock:
            u = self._rng.random(flat.size, np.float32)
        q = np.floor(flat / scale + u)
        return np.clip(q, -127, 127).astype(np.int8), scale

    @staticmethod
    def dequantize(q: np.ndarray, scale: float) -> np.ndarray:
        return q.astype(np.float32) * np.float32(scale)

    def compress(self, key: str, flat: np.ndarray):
        q, scale = self.quantize(flat)
        idx = np.arange(q.size, dtype=np.int64)
        return idx, self.dequantize(q, scale)

    def wire_bytes(self, idx: np.ndarray, vals: np.ndarray) -> int:
        return int(np.asarray(vals).size) * 1 + 4


def make_codec(spec, seed: int = 0):
    """Parse a codec spec: None/'none', 'topk:<ratio>', a bare float (ratio),
    or 'int8'. Returns a codec object or None."""
    if spec is None:
        return None
    if isinstance(spec, (int, float)):
        return ErrorFeedbackCompressor(float(spec))
    s = str(spec).strip().lower()
    if s in ("", "none", "off"):
        return None
    if s == "int8":
        return Int8StochasticQuantizer(seed)
    if s.startswith("topk:"):
        return ErrorFeedbackCompressor(float(s.split(":", 1)[1]))
    try:
        return ErrorFeedbackCompressor(float(s))
    except ValueError:
        raise ValueError(f"unknown codec spec: {spec!r}") from None
