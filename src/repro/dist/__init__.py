"""repro.dist — the communication subsystem for WSP data parallelism.

HetPipe's headline saving is communication-side: virtual workers push one
wave-aggregated delta per wave (Section 5), and the partitioner folds a
profiled network model into stage placement (Section 7). This package models
that layer at host level:

  compression  — sparsifying / quantizing codecs with error feedback
  topology     — heterogeneous cluster/link cost model (alpha-beta)
  collectives  — emulated ring / hierarchical reduction algorithms
  transport    — simulated per-link delay + byte accounting for the PS path

Everything here is numpy/threading level (no device code): it is the analogue
of the paper's profiled-network planning, usable both for analytic reports
(allocation, benchmarks) and for injecting real waiting into the threaded
WSP runtime.
"""
from repro.dist.compression import (            # noqa: F401
    ErrorFeedbackCompressor, Int8StochasticQuantizer, make_codec,
    topk_compress, topk_decompress,
)
from repro.dist.topology import (               # noqa: F401
    ClusterTopology, LinkSpec, Pod, make_topology,
)
from repro.dist.collectives import (            # noqa: F401
    ring_allreduce, ring_reduce_scatter, ring_all_gather,
    hierarchical_allreduce,
)
from repro.dist.transport import (              # noqa: F401
    NullTransport, SimulatedTransport,
)
