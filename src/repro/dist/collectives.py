"""Host-level emulation of the collectives the SPMD path would run on device.

Each collective takes one pytree per participating worker, actually executes
the algorithm's communication schedule on numpy buffers (chunked ring
reduce-scatter / all-gather, pod-hierarchical reduce), and returns

    (reduced_tree, predicted_seconds)

where the cost comes from a ClusterTopology alpha-beta model (0.0 when no
topology is given). The emulation reproduces the algorithm's arithmetic
ordering, so results match a flat numpy sum only to float32 tolerance —
exactly the property tests assert.

These are the "next steps" named in benchmarks/roofline.py's collective
hint: hierarchical pod-local-then-cross-pod reduce and (via repro.dist.
compression) gradient compression.
"""
from __future__ import annotations

import jax
import numpy as np


# -- pytree <-> flat vector ----------------------------------------------

def _stack_flat(trees):
    """Flatten each worker's pytree into one float32 vector; all trees must
    share a treedef. Returns (vectors, spec) for _unflatten."""
    assert trees, "need at least one worker tree"
    leaves0, treedef = jax.tree.flatten(trees[0])
    shapes = [np.shape(l) for l in leaves0]
    dtypes = [np.asarray(l).dtype for l in leaves0]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    vecs = []
    for t in trees:
        leaves, td = jax.tree.flatten(t)
        assert td == treedef, "workers disagree on tree structure"
        if leaves:
            vecs.append(np.concatenate(
                [np.asarray(l, np.float32).ravel() for l in leaves]))
        else:
            vecs.append(np.zeros(0, np.float32))
    return vecs, (treedef, shapes, dtypes, sizes)


def _unflatten(vec, spec):
    treedef, shapes, dtypes, sizes = spec
    out, off = [], 0
    for sh, dt, sz in zip(shapes, dtypes, sizes):
        out.append(vec[off:off + sz].reshape(sh).astype(dt))
        off += sz
    return jax.tree.unflatten(treedef, out)


def _chunk_slices(n: int, W: int):
    bounds = np.linspace(0, n, W + 1).astype(np.int64)
    return [slice(int(bounds[c]), int(bounds[c + 1])) for c in range(W)]


# -- ring schedule --------------------------------------------------------

def ring_reduce_scatter(vectors: list[np.ndarray]) -> list[np.ndarray]:
    """Run the W-1-step ring reduce-scatter schedule; returns the W summed
    chunks (chunk c fully reduced, as held by its final owner)."""
    W = len(vectors)
    if W == 1:
        return [vectors[0].copy()]
    n = vectors[0].size
    sl = _chunk_slices(n, W)
    acc = [v.astype(np.float32).copy() for v in vectors]
    for step in range(W - 1):
        # worker i sends chunk (i - step) mod W to its ring successor; stage
        # all sends first so a step's transfers are simultaneous
        staged = [(i, (i - step) % W, acc[i][sl[(i - step) % W]].copy())
                  for i in range(W)]
        for i, c, data in staged:
            acc[(i + 1) % W][sl[c]] += data
    # after W-1 hops chunk c has been fully accumulated at worker (c-1) mod W
    return [acc[(c - 1) % W][sl[c]] for c in range(W)]


def ring_all_gather(chunks: list[np.ndarray]) -> np.ndarray:
    """All-gather of the reduced chunks (every worker ends with the concat;
    the schedule is W-1 forwarding steps — data-independent, so we return
    the concatenation directly)."""
    return np.concatenate([np.asarray(c, np.float32) for c in chunks])


def _worker_names(topology, workers, W):
    if workers is not None:
        assert len(workers) == W, (len(workers), W)
        return list(workers)
    if topology is not None:
        names = topology.worker_names()
        assert len(names) >= W, "topology has fewer workers than trees"
        return names[:W]
    return [f"vw{i}" for i in range(W)]


# -- public collectives ---------------------------------------------------

def ring_allreduce(trees, *, topology=None, workers=None,
                   average: bool = False):
    """Bandwidth-optimal ring all-reduce over one pytree per worker.

    Returns (tree, seconds): the element-wise sum (or mean) in the first
    worker's dtypes, plus the topology-predicted time (0.0 untimed).
    """
    vecs, spec = _stack_flat(trees)
    W = len(vecs)
    names = _worker_names(topology, workers, W)
    total = ring_all_gather(ring_reduce_scatter(vecs))
    if average:
        total = total / np.float32(W)
    nbytes = total.nbytes
    cost = (topology.ring_allreduce_cost(names, nbytes)
            if topology is not None else 0.0)
    return _unflatten(total, spec), cost


def hierarchical_allreduce(trees, *, topology=None, workers=None,
                           average: bool = False):
    """Pod-local ring reduce, then a cross-pod ring over pod leaders, then
    pod-local broadcast — the full vector crosses the slow inter-pod tier
    only 2(P-1)/P times. With no topology it degenerates to one pod."""
    vecs, spec = _stack_flat(trees)
    W = len(vecs)
    names = _worker_names(topology, workers, W)
    if topology is None:
        groups = {"pod0": list(range(W))}
    else:
        groups = {}
        for i, w in enumerate(names):
            groups.setdefault(topology._resolve(w).name, []).append(i)
    # stage 1: pod-local ring reduce to one partial sum per pod
    partials = []
    for idxs in groups.values():
        partials.append(ring_all_gather(
            ring_reduce_scatter([vecs[i] for i in idxs])))
    # stage 2: leader ring across pods (broadcast back is data-identical)
    total = ring_all_gather(ring_reduce_scatter(partials))
    if average:
        total = total / np.float32(W)
    nbytes = total.nbytes
    cost = (topology.hierarchical_allreduce_cost(names, nbytes)
            if topology is not None else 0.0)
    return _unflatten(total, spec), cost
