"""Heterogeneous network topology cost model (paper Sections 5, 7).

HetPipe's partitioner profiles the network and folds link costs into stage
placement; its experiments run on nodes with fast intra-node interconnect
(NVLink/PCIe) joined by slower Ethernet or InfiniBand. This module models
exactly that two-tier structure with an alpha-beta (latency + bytes/bandwidth)
cost per link, and prices point-to-point transfers and collectives over a
worker fleet.

Workers are string ids ("vw0", ...). The special endpoint "ps" is the
parameter server, hosted on a configurable worker's pod (HetPipe co-locates
PS shards with nodes; `ps_host` models the 'local' placement).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.partition import DeviceProfile


@dataclass(frozen=True)
class LinkSpec:
    """alpha-beta link: transfer_time(b) = latency + b / bandwidth."""
    name: str
    gbps: float               # payload bandwidth, GB/s
    latency_s: float = 0.0    # per-message latency (alpha)

    def transfer_time(self, nbytes: float) -> float:
        if nbytes <= 0:
            return 0.0
        return self.latency_s + nbytes / (self.gbps * 1e9)


# Canonical link classes (order-of-magnitude realistic, not vendor-exact).
NVLINK = LinkSpec("nvlink", 150.0, 2e-6)
PCIE = LinkSpec("pcie", 12.0, 5e-6)
IB_100G = LinkSpec("ib100", 12.5, 2e-5)
ETH_10G = LinkSpec("eth10", 1.25, 1e-4)      # the paper's 10 Gbps Ethernet
ETH_1G = LinkSpec("eth1", 0.125, 1e-4)       # whimpy-cluster 1 GbE
ZERO_LINK = LinkSpec("zero", math.inf, 0.0)


@dataclass(frozen=True)
class Pod:
    """One physical node: a set of workers joined by an intra-node link."""
    name: str
    workers: tuple[str, ...]
    intra: LinkSpec = NVLINK


class ClusterTopology:
    def __init__(self, pods: list[Pod], inter: LinkSpec = ETH_10G,
                 ps_host: str | None = None):
        assert pods, "topology needs at least one pod"
        self.pods = list(pods)
        self.inter = inter
        self.pod_of: dict[str, Pod] = {}
        for p in self.pods:
            for w in p.workers:
                assert w not in self.pod_of, f"duplicate worker {w}"
                self.pod_of[w] = p
        self.ps_host = ps_host or self.pods[0].workers[0]
        assert self.ps_host in self.pod_of, self.ps_host
        self._aliases: dict[str, str] = {}

    def add_alias(self, wid: str, host_wid: str):
        """Map an extra endpoint (e.g. an elastically re-joined worker) onto
        an existing worker's pod."""
        assert host_wid in self.pod_of, host_wid
        self._aliases[wid] = host_wid

    # -- structure --------------------------------------------------------
    def worker_names(self) -> list[str]:
        return [w for p in self.pods for w in p.workers]

    def _resolve(self, endpoint: str) -> Pod:
        if endpoint == "ps":
            endpoint = self.ps_host
        endpoint = self._aliases.get(endpoint, endpoint)
        pod = self.pod_of.get(endpoint)
        if pod is None:
            raise KeyError(f"unknown endpoint {endpoint!r}; "
                           f"workers={self.worker_names()}")
        return pod

    def link(self, a: str, b: str) -> LinkSpec:
        pa, pb = self._resolve(a), self._resolve(b)
        return pa.intra if pa is pb else self.inter

    def path_links(self, names: list[str]) -> list[LinkSpec]:
        """Links between consecutive endpoints — e.g. the boundary links of
        a pipeline whose stage devices are the named workers, in stage
        order. Feed to core.partition.partition_minmax(links=...)."""
        return [self.link(a, b) for a, b in zip(names, names[1:])]

    # -- point-to-point ---------------------------------------------------
    def p2p_cost(self, a: str, b: str, nbytes: float) -> float:
        """Seconds to move nbytes from a to b ('ps' = the parameter server).
        A worker talking to a PS shard hosted on itself costs nothing."""
        if a == b or {a, b} == {"ps", self.ps_host}:
            return 0.0
        return self.link(a, b).transfer_time(nbytes)

    # -- collectives (alpha-beta ring model) ------------------------------
    def _ring_links(self, workers: list[str]) -> list[LinkSpec]:
        W = len(workers)
        return [self.link(workers[i], workers[(i + 1) % W])
                for i in range(W)]

    def _ring_steps_cost(self, workers: list[str], nbytes: float,
                         steps: int) -> float:
        """`steps` ring steps each moving nbytes/W over the slowest hop."""
        W = len(workers)
        if W <= 1 or nbytes <= 0:
            return 0.0
        links = self._ring_links(workers)
        alpha = max(l.latency_s for l in links)
        beta = min(l.gbps for l in links) * 1e9
        chunk = nbytes / W
        return steps * (alpha + chunk / beta)

    def reduce_scatter_cost(self, workers: list[str], nbytes: float) -> float:
        return self._ring_steps_cost(workers, nbytes, len(workers) - 1)

    def all_gather_cost(self, workers: list[str], nbytes: float) -> float:
        return self._ring_steps_cost(workers, nbytes, len(workers) - 1)

    def ring_allreduce_cost(self, workers: list[str], nbytes: float) -> float:
        """Bandwidth-optimal ring: 2(W-1) steps of nbytes/W, gated by the
        slowest hop — on a pod-spanning ring that is the inter-pod link."""
        return self._ring_steps_cost(workers, nbytes, 2 * (len(workers) - 1))

    def hierarchical_allreduce_cost(self, workers: list[str],
                                    nbytes: float) -> float:
        """Pod-local ring reduce + cross-pod leader ring + pod-local
        broadcast: the full vector crosses the slow tier only 2(P-1)/P times
        instead of 2(W-1)/W."""
        by_pod: dict[str, list[str]] = {}
        for w in workers:
            by_pod.setdefault(self._resolve(w).name, []).append(w)
        local = max((self.ring_allreduce_cost(ws, nbytes)
                     for ws in by_pod.values()), default=0.0)
        leaders = [ws[0] for ws in by_pod.values()]
        cross = self.ring_allreduce_cost(leaders, nbytes)
        bcast = max((self.all_gather_cost(ws, nbytes)
                     for ws in by_pod.values() if len(ws) > 1), default=0.0)
        return local + cross + bcast

    def allreduce_cost(self, workers: list[str], nbytes: float,
                       algo: str = "ring") -> float:
        if algo == "ring":
            return self.ring_allreduce_cost(workers, nbytes)
        if algo == "hierarchical":
            return self.hierarchical_allreduce_cost(workers, nbytes)
        raise ValueError(algo)

    # -- builders ---------------------------------------------------------
    @classmethod
    def from_fleet(cls, nodes, num_vw: int | None = None,
                   inter: LinkSpec = ETH_10G,
                   node_latency_s: float = 1e-5) -> "ClusterTopology":
        """Build a topology from allocation-style nodes (objects with .gpu
        DeviceProfile and .count). Intra-node bandwidth comes from the
        device profile's link_gbps; virtual worker i is hosted on node
        i % len(nodes) (each VW's PS traffic egresses from one node)."""
        num_vw = len(nodes) if num_vw is None else num_vw
        hosted: list[list[str]] = [[] for _ in nodes]
        for i in range(num_vw):
            hosted[i % len(nodes)].append(f"vw{i}")
        pods = []
        for j, (n, ws) in enumerate(zip(nodes, hosted)):
            gpu: DeviceProfile = n.gpu
            intra = LinkSpec(f"{gpu.name.lower().replace(' ', '-')}-link",
                             gpu.link_gbps, node_latency_s)
            pods.append(Pod(f"node{j}", tuple(ws), intra))
        return cls([p for p in pods if p.workers] or pods[:1], inter=inter)


def stage_links(devices: list[DeviceProfile], inter: LinkSpec = ETH_10G,
                node_latency_s: float = 1e-5) -> list[LinkSpec]:
    """Boundary links for a pipeline over `devices` (stage order), for the
    partitioner's link-aware stage_time.

    Allocation policies hand a VW an *ordered* device list in which
    consecutive devices of the same profile share a node (NP keeps whole
    nodes; ED/HD sort by type), so a profile change at a stage boundary
    means the activation crosses the cluster's inter-node link — the
    paper's profiled-network input to placement (Section 7)."""
    links = []
    for a, b in zip(devices, devices[1:]):
        if a.name == b.name:
            links.append(LinkSpec(
                f"{a.name.lower().replace(' ', '-')}-intra",
                a.link_gbps, node_latency_s))
        else:
            links.append(inter)
    return links


def _split_contiguous(num_vw: int, parts: int) -> list[tuple[str, ...]]:
    return [tuple(f"vw{int(i)}" for i in chunk)
            for chunk in np.array_split(np.arange(num_vw), parts)]


# spec -> one-line description; the parseable grammar for make_topology and
# the source of truth for `--topology list` in the CLI.
TOPOLOGY_SPECS: dict[str, str] = {
    "none":            "no network model (zero-latency default; "
                       "aliases: '', 'zero', 'off')",
    "single":          "one NVLink pod holding every virtual worker",
    "<k>node[:LINK]":  "k NVLink pods joined by LINK: 'eth' (10 GbE, "
                       "default), 'eth1' (whimpy 1 GbE) or 'ib' (100G IB) "
                       "— e.g. '2node', '4node:ib', '2node:eth1'",
    "hetero[-2node]":  "an NVLink pod + a PCIe pod over 10 GbE",
    "paper":           "the paper's 4-node V/R/G/Q fleet (Table 1), intra "
                       "links from the device profiles",
}

_INTER_LINKS = {"": ETH_10G, "eth": ETH_10G, "eth10": ETH_10G,
                "eth1": ETH_1G, "ib": IB_100G}


def topology_help() -> str:
    """Human-readable listing of every accepted --topology spec."""
    width = max(len(k) for k in TOPOLOGY_SPECS)
    return "\n".join(f"  {k:<{width}}  {v}"
                     for k, v in TOPOLOGY_SPECS.items())


def make_topology(spec: str | None, num_vw: int) -> ClusterTopology | None:
    """Parse a CLI/topology spec into a ClusterTopology over vw0..vw{N-1}.

    See TOPOLOGY_SPECS / topology_help() for the grammar. Unknown or
    malformed specs raise ValueError with the full listing rather than
    failing deep inside parsing.
    """
    if spec is None:
        return None
    s = str(spec).strip().lower()
    if s in ("", "none", "zero", "off"):
        return None
    if s == "single":
        return ClusterTopology(
            [Pod("node0", tuple(f"vw{i}" for i in range(num_vw)), NVLINK)])
    if s in ("hetero", "hetero-2node"):
        a, b = _split_contiguous(num_vw, 2)
        return ClusterTopology([Pod("node0", a, NVLINK),
                                Pod("node1", b, PCIE)], inter=ETH_10G)
    if s == "paper":
        from repro.core.allocation import Node
        from repro.core.partition import PAPER_GPUS
        return ClusterTopology.from_fleet(
            [Node(PAPER_GPUS[c], 4) for c in "VRGQ"], num_vw=num_vw)
    if s.endswith("node") or ":" in s:
        base, _, linkname = s.partition(":")
        if linkname not in _INTER_LINKS:
            raise ValueError(
                f"unknown inter-node link {linkname!r} in topology spec "
                f"{spec!r}; expected one of "
                f"{sorted(k for k in _INTER_LINKS if k)}")
        inter = _INTER_LINKS[linkname]
        try:
            k = int(base.removesuffix("node"))
        except ValueError:
            raise ValueError(
                f"malformed topology spec {spec!r}: expected '<k>node' with "
                f"integer k, got {base!r}. Known specs:\n"
                + topology_help()) from None
        if k < 1:
            raise ValueError(
                f"topology spec {spec!r} needs at least one node (k >= 1)")
        groups = _split_contiguous(num_vw, min(k, num_vw))
        pods = [Pod(f"node{j}", g, NVLINK)
                for j, g in enumerate(groups) if g]
        return ClusterTopology(pods, inter=inter)
    raise ValueError(f"unknown topology spec {spec!r}. Known specs:\n"
                     + topology_help())
