"""Simulated transport: per-link delay, fault injection and byte accounting.

The threaded WSP runtime models heterogeneous *compute* with per-VW speed
factors; this transport adds the *network* side. Every ParameterServer
push/pull routes through Transport.send(src, dst, nbytes), which

  - prices the message on the topology's link (alpha + bytes/beta),
  - sleeps for that time (scaled by time_scale so experiments stay fast),
  - serializes concurrent messages on the same link (a per-link lock — the
    simple contention model: a link is a shared resource, transfers queue),
  - accounts bytes and modeled seconds per link for the training report.

send_async() starts a transfer without blocking the caller: it accounts the
message immediately and returns an AsyncSend handle whose wait() performs the
(scaled, link-serialized) delay. A background pusher calling wait() while the
issuing thread keeps computing is how the runtime charges max(compute, comm)
per wave instead of the sum.

Fault injection (repro.faults): when built with an injector, every message
consults it per *attempt*. A dropped attempt costs the policy's modeled
per-message timeout, then the transport retries under capped exponential
backoff up to `max_retries`; a degraded attempt pays a multiplied link
cost. Drops and retries are accounted per link (`stats()['drops_by_link'
/'retries_by_link']`), and a message whose retry budget is exhausted
raises the typed `TransportError` from wait() — the ParameterServer turns
that into a PushTimeout on the push path. The verdicts come from the
seeded FaultPlan keyed on per-path message counters, so the fault
sequence — and therefore every drop/retry counter — is deterministic
across runs.

NullTransport is the zero-latency default: pure accounting, no waiting
(faults still inject if an injector is attached; only the sleeps vanish).
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict


class AsyncSend:
    """Handle for an in-flight transfer.

    `seconds` is the modeled (unscaled) link time — including retries and
    failed-attempt timeouts — known at issue time. wait() performs the
    scaled sleep (serialized per link) exactly once and is safe to call
    from any thread; done() reports completion without blocking. A
    transfer that terminally failed raises its TransportError from wait()
    (every waiter sees the same error).
    """

    def __init__(self, seconds: float = 0.0, waiter=None, exc=None):
        self.seconds = float(seconds)
        self._waiter = waiter
        self._exc = exc
        self._done = threading.Event()
        self._wait_lock = threading.Lock()
        if waiter is None:
            self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self) -> float:
        if not self._done.is_set():
            with self._wait_lock:                # first waiter pays the delay
                if not self._done.is_set():
                    try:
                        self._waiter()
                    except Exception as e:
                        self._exc = e
                    finally:
                        self._done.set()
        self._done.wait()
        if self._exc is not None:
            raise self._exc
        return self.seconds


class NullTransport:
    """Zero-cost transport: counts bytes (and faults), never sleeps."""

    def __init__(self, *, injector=None, policy=None):
        self.bytes_by_link = defaultdict(int)
        self.seconds_by_link = defaultdict(float)
        self.drops_by_link = defaultdict(int)
        self.retries_by_link = defaultdict(int)
        self.injector = injector
        if policy is None and injector is not None:
            from repro.faults.plan import FaultPolicy
            policy = FaultPolicy()
        self.policy = policy
        self._stats_lock = threading.Lock()

    def _consult(self, src: str, dst: str):
        """(attempts [(ok, cost_factor)], drops, retries, ok) for one
        message; the no-injector fast path is a single clean attempt."""
        if self.injector is None:
            return [(True, 1.0)], 0, 0, True
        att = self.injector.message_attempts(
            src, dst, 1 + self.policy.max_retries)
        ok = att[-1][0]
        retries = len(att) - 1
        drops = retries + (0 if ok else 1)
        return att, drops, retries, ok

    def _account_faults(self, name: str, drops: int, retries: int) -> None:
        if drops or retries:
            with self._stats_lock:
                self.drops_by_link[name] += drops
                self.retries_by_link[name] += retries

    def send_async(self, src: str, dst: str, nbytes: int) -> AsyncSend:
        att, drops, retries, ok = self._consult(src, dst)
        with self._stats_lock:
            self.bytes_by_link["loopback"] += int(nbytes)
        self._account_faults("loopback", drops, retries)
        if not ok:
            from repro.faults.errors import TransportError
            return AsyncSend(0.0, exc=TransportError(
                src, dst, "loopback", len(att), int(nbytes)))
        return AsyncSend(0.0)

    def send(self, src: str, dst: str, nbytes: int) -> float:
        return self.send_async(src, dst, nbytes).wait()

    def stats(self) -> dict:
        return {"bytes_by_link": dict(self.bytes_by_link),
                "seconds_by_link": dict(self.seconds_by_link),
                "drops_by_link": dict(self.drops_by_link),
                "retries_by_link": dict(self.retries_by_link),
                "drops": sum(self.drops_by_link.values()),
                "retries": sum(self.retries_by_link.values()),
                "modeled_seconds": sum(self.seconds_by_link.values())}


class SimulatedTransport(NullTransport):
    def __init__(self, topology, *, time_scale: float = 1.0,
                 max_sleep_per_msg: float = 0.25, tracer=None,
                 injector=None, policy=None):
        super().__init__(injector=injector, policy=policy)
        self.topology = topology
        self.time_scale = float(time_scale)
        self.max_sleep_per_msg = float(max_sleep_per_msg)
        self._link_locks: dict[str, threading.Lock] = defaultdict(
            threading.Lock)
        self._reg_lock = threading.Lock()
        if tracer is None:
            from repro.obs import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer

    def _lock_for(self, link_name: str) -> threading.Lock:
        with self._reg_lock:
            return self._link_locks[link_name]

    def send_async(self, src: str, dst: str, nbytes: int) -> AsyncSend:
        """Account the message now; the returned handle's wait() pays the
        scaled delay under the link lock (contention) when called. The
        whole retry schedule (verdicts, backoffs, degradation factors) is
        fixed at issue time from the deterministic per-path counters."""
        nbytes = int(nbytes)
        cost = self.topology.p2p_cost(src, dst, nbytes)
        link = self.topology.link(src, dst) if cost > 0 else None
        name = link.name if link is not None else "local"
        att, drops, retries, ok = self._consult(src, dst)
        # modeled seconds: each failed attempt pays the message timeout
        # plus its capped exponential backoff; the final attempt (if any
        # succeeded) pays the link cost times its degradation factor
        modeled = cost * att[-1][1] if ok else 0.0
        if drops or retries:
            pol = self.policy
            for i in range(retries + (0 if ok else 1)):
                modeled += pol.msg_timeout_s + min(
                    pol.backoff_base_s * (2 ** i), pol.backoff_cap_s)
        with self._stats_lock:
            self.bytes_by_link[name] += nbytes
            self.seconds_by_link[name] += modeled
        self._account_faults(name, drops, retries)
        fail_exc = None
        if not ok:
            from repro.faults.errors import TransportError
            fail_exc = TransportError(src, dst, name, len(att), nbytes)
        if modeled <= 0 and fail_exc is None:
            return AsyncSend(0.0)
        delay = min(modeled * self.time_scale, self.max_sleep_per_msg)
        tracer = self.tracer

        def waiter():
            # holding the link lock while sleeping serializes transfers that
            # share the link — concurrent pushers contend for bandwidth
            # (the span covers queueing *and* the wire, so per-link tracks
            # show contention as back-to-back transfers)
            with tracer.span(f"link:{name}", "send", src=src, dst=dst,
                             bytes=nbytes, modeled_s=modeled,
                             retries=retries):
                if drops:
                    tracer.instant(f"link:{name}", "drop", src=src, dst=dst,
                                   drops=drops, retries=retries)
                    tracer.metrics.counter_inc("fault/drops", drops)
                    tracer.metrics.counter_inc("fault/retries", retries)
                with self._lock_for(name):
                    if delay > 0:
                        time.sleep(delay)
            if fail_exc is not None:
                raise fail_exc

        return AsyncSend(modeled, waiter, None)
