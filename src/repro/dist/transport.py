"""Simulated transport: per-link delay and byte accounting for the PS path.

The threaded WSP runtime models heterogeneous *compute* with per-VW speed
factors; this transport adds the *network* side. Every ParameterServer
push/pull routes through Transport.send(src, dst, nbytes), which

  - prices the message on the topology's link (alpha + bytes/beta),
  - sleeps for that time (scaled by time_scale so experiments stay fast),
  - serializes concurrent messages on the same link (a per-link lock — the
    simple contention model: a link is a shared resource, transfers queue),
  - accounts bytes and modeled seconds per link for the training report.

NullTransport is the zero-latency default: pure accounting, no waiting.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict


class NullTransport:
    """Zero-cost transport: counts bytes, never sleeps."""

    def __init__(self):
        self.bytes_by_link = defaultdict(int)
        self.seconds_by_link = defaultdict(float)
        self._stats_lock = threading.Lock()

    def send(self, src: str, dst: str, nbytes: int) -> float:
        with self._stats_lock:
            self.bytes_by_link["loopback"] += int(nbytes)
        return 0.0

    def stats(self) -> dict:
        return {"bytes_by_link": dict(self.bytes_by_link),
                "seconds_by_link": dict(self.seconds_by_link),
                "modeled_seconds": sum(self.seconds_by_link.values())}


class SimulatedTransport(NullTransport):
    def __init__(self, topology, *, time_scale: float = 1.0,
                 max_sleep_per_msg: float = 0.25):
        super().__init__()
        self.topology = topology
        self.time_scale = float(time_scale)
        self.max_sleep_per_msg = float(max_sleep_per_msg)
        self._link_locks: dict[str, threading.Lock] = defaultdict(
            threading.Lock)
        self._reg_lock = threading.Lock()

    def _lock_for(self, link_name: str) -> threading.Lock:
        with self._reg_lock:
            return self._link_locks[link_name]

    def send(self, src: str, dst: str, nbytes: int) -> float:
        """Returns the modeled (unscaled) transfer seconds."""
        nbytes = int(nbytes)
        cost = self.topology.p2p_cost(src, dst, nbytes)
        link = self.topology.link(src, dst) if cost > 0 else None
        name = link.name if link is not None else "local"
        with self._stats_lock:
            self.bytes_by_link[name] += nbytes
            self.seconds_by_link[name] += cost
        if cost > 0:
            delay = min(cost * self.time_scale, self.max_sleep_per_msg)
            # holding the link lock while sleeping serializes transfers that
            # share the link — concurrent pushers contend for bandwidth
            with self._lock_for(name):
                time.sleep(delay)
        return cost
