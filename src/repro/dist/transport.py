"""Simulated transport: per-link delay and byte accounting for the PS path.

The threaded WSP runtime models heterogeneous *compute* with per-VW speed
factors; this transport adds the *network* side. Every ParameterServer
push/pull routes through Transport.send(src, dst, nbytes), which

  - prices the message on the topology's link (alpha + bytes/beta),
  - sleeps for that time (scaled by time_scale so experiments stay fast),
  - serializes concurrent messages on the same link (a per-link lock — the
    simple contention model: a link is a shared resource, transfers queue),
  - accounts bytes and modeled seconds per link for the training report.

send_async() starts a transfer without blocking the caller: it accounts the
message immediately and returns an AsyncSend handle whose wait() performs the
(scaled, link-serialized) delay. A background pusher calling wait() while the
issuing thread keeps computing is how the runtime charges max(compute, comm)
per wave instead of the sum.

NullTransport is the zero-latency default: pure accounting, no waiting.
"""
from __future__ import annotations

import threading
import time
from collections import defaultdict


class AsyncSend:
    """Handle for an in-flight transfer.

    `seconds` is the modeled (unscaled) link time, known at issue time.
    wait() performs the scaled sleep (serialized per link) exactly once and
    is safe to call from any thread; done() reports completion without
    blocking.
    """

    def __init__(self, seconds: float = 0.0, waiter=None):
        self.seconds = float(seconds)
        self._waiter = waiter
        self._done = threading.Event()
        self._wait_lock = threading.Lock()
        if waiter is None:
            self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self) -> float:
        if not self._done.is_set():
            with self._wait_lock:                # first waiter pays the delay
                if not self._done.is_set():
                    self._waiter()
                    self._done.set()
        self._done.wait()
        return self.seconds


class NullTransport:
    """Zero-cost transport: counts bytes, never sleeps."""

    def __init__(self):
        self.bytes_by_link = defaultdict(int)
        self.seconds_by_link = defaultdict(float)
        self._stats_lock = threading.Lock()

    def send_async(self, src: str, dst: str, nbytes: int) -> AsyncSend:
        with self._stats_lock:
            self.bytes_by_link["loopback"] += int(nbytes)
        return AsyncSend(0.0)

    def send(self, src: str, dst: str, nbytes: int) -> float:
        return self.send_async(src, dst, nbytes).wait()

    def stats(self) -> dict:
        return {"bytes_by_link": dict(self.bytes_by_link),
                "seconds_by_link": dict(self.seconds_by_link),
                "modeled_seconds": sum(self.seconds_by_link.values())}


class SimulatedTransport(NullTransport):
    def __init__(self, topology, *, time_scale: float = 1.0,
                 max_sleep_per_msg: float = 0.25, tracer=None):
        super().__init__()
        self.topology = topology
        self.time_scale = float(time_scale)
        self.max_sleep_per_msg = float(max_sleep_per_msg)
        self._link_locks: dict[str, threading.Lock] = defaultdict(
            threading.Lock)
        self._reg_lock = threading.Lock()
        if tracer is None:
            from repro.obs import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer

    def _lock_for(self, link_name: str) -> threading.Lock:
        with self._reg_lock:
            return self._link_locks[link_name]

    def send_async(self, src: str, dst: str, nbytes: int) -> AsyncSend:
        """Account the message now; the returned handle's wait() pays the
        scaled delay under the link lock (contention) when called."""
        nbytes = int(nbytes)
        cost = self.topology.p2p_cost(src, dst, nbytes)
        link = self.topology.link(src, dst) if cost > 0 else None
        name = link.name if link is not None else "local"
        with self._stats_lock:
            self.bytes_by_link[name] += nbytes
            self.seconds_by_link[name] += cost
        if cost <= 0:
            return AsyncSend(0.0)
        delay = min(cost * self.time_scale, self.max_sleep_per_msg)
        tracer = self.tracer

        def waiter():
            # holding the link lock while sleeping serializes transfers that
            # share the link — concurrent pushers contend for bandwidth
            # (the span covers queueing *and* the wire, so per-link tracks
            # show contention as back-to-back transfers)
            with tracer.span(f"link:{name}", "send", src=src, dst=dst,
                             bytes=nbytes, modeled_s=cost):
                with self._lock_for(name):
                    time.sleep(delay)

        return AsyncSend(cost, waiter)
