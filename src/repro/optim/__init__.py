from repro.optim.optimizers import (
    Optimizer, make_optimizer, sgd, momentum, adamw, warmup_cosine,
)
