"""Delta-producing optimizers.

WSP's parameter servers apply *additive deltas* (w_global += u). Local
optimizers therefore transform wave gradients into deltas; adaptive state
(momentum/Adam moments) stays virtual-worker-local, exactly as parameter-server
deployments run adaptive optimizers. The WSP convergence proof covers SGD;
momentum/AdamW are provided for the LM examples (see DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable            # params -> state
    update: Callable          # (grads, state, params, step) -> (deltas, state)
    name: str = ""


def _tree_zeros(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd(lr):
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        lr_t = lr(state["step"]) if callable(lr) else lr
        deltas = jax.tree.map(lambda g: -lr_t * g, grads)
        return deltas, {"step": state["step"] + 1}

    return Optimizer(init, update, "sgd")


def momentum(lr, mu=0.9):
    def init(params):
        return {"m": _tree_zeros(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        lr_t = lr(state["step"]) if callable(lr) else lr
        m = jax.tree.map(lambda m_, g: mu * m_ + g, state["m"], grads)
        deltas = jax.tree.map(lambda m_: -lr_t * m_, m)
        return deltas, {"m": m, "step": state["step"] + 1}

    return Optimizer(init, update, "momentum")


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1):
    def init(params):
        return {"m": _tree_zeros(params), "v": _tree_zeros(params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step=None):
        t = state["step"] + 1
        lr_t = lr(state["step"]) if callable(lr) else lr
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def delta(m_, v_, p):
            upd = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            return -lr_t * (upd + weight_decay * p)

        deltas = jax.tree.map(delta, m, v, params)
        return deltas, {"m": m, "v": v, "step": t}

    return Optimizer(init, update, "adamw")


def make_optimizer(name: str, lr, weight_decay: float = 0.1) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay)
    raise ValueError(name)


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr
