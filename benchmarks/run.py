"""Benchmark harness: one function per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
measured run where applicable; derived = the figure's headline quantity).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import paper_benchmarks as pb

    benches = [
        pb.fig3_nm_sweep,
        pb.fig4_allocation_policies,
        pb.table4_whimpy_scaling,
        pb.fig5_6_convergence,
        pb.sec84_wait_time,
        pb.wave_sync_comm_saving,
    ]
    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived:.6g}")
                sys.stdout.flush()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{bench.__name__},0.0,ERROR")
            traceback.print_exc()
    # roofline summary (from dry-run artifacts, if present)
    try:
        from benchmarks.roofline import table
        rows = table()
        if rows:
            best = max(rows, key=lambda r: r["roofline_frac"])
            for r in rows:
                print(f"roofline/{r['cell']},0.0,{r['roofline_frac']:.6g}")
            print(f"roofline/best_cell,0.0,{best['roofline_frac']:.6g}")
    except Exception:  # noqa: BLE001
        traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
