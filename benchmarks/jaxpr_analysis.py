"""Trip-count-aware cost analysis over jaxprs.

XLA's compiled.cost_analysis() counts while-loop bodies ONCE (verified in this
container: an 8-iteration scan of a 128x128 matmul reports 1 iteration of
FLOPs). Our pipeline is a scan over ticks with nested attention/SSM scans, so
HLO cost_analysis undercounts by orders of magnitude. This module walks the
traced jaxpr instead, multiplying scan lengths, so every roofline term counts
the computation that actually executes.

Conventions:
  - inside shard_map, shapes are per-device blocks -> counts are per-device.
  - outside shard_map (GSPMD-auto region: embedding, loss head, optimizer),
    shapes are global; counts are divided by the device count (the CE/embed
    ops are sharded over the full mesh; optimizer noise is negligible).
  - collectives: ring-model per-device link bytes:
      psum 2(n-1)/n * payload | all_gather/reduce_scatter (n-1)/n * gathered
      ppermute 1x payload | all_to_all (n-1)/n * payload
  - cond/switch branches: max over branches (one branch executes per layer).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import numpy as np


@dataclass
class Costs:
    flops: float = 0.0              # total (dot + elementwise)
    dot_flops: float = 0.0          # matmul-only
    bytes_upper: float = 0.0        # unfused sum of eqn in+out bytes
    dot_bytes: float = 0.0          # dot operands+outputs only
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)
    bytes_by_prim: dict = field(default_factory=dict)  # attribution
    kern_dot_bytes: float = 0.0     # f32xf32 dots inside shard_map: these are
    kern_dot_flops: float = 0.0     # the flash/SSM interiors that the Pallas
                                    # kernels keep VMEM-resident on TPU

    def add_coll(self, kind: str, nbytes: float, times: float):
        self.collective_bytes[kind] = self.collective_bytes.get(kind, 0.0) \
            + nbytes * times
        self.collective_counts[kind] = self.collective_counts.get(kind, 0) \
            + times

    def scaled(self, f: float):
        return Costs(self.flops * f, self.dot_flops * f, self.bytes_upper * f,
                     self.dot_bytes * f,
                     {k: v * f for k, v in self.collective_bytes.items()},
                     {k: v * f for k, v in self.collective_counts.items()},
                     {k: v * f for k, v in self.bytes_by_prim.items()},
                     self.kern_dot_bytes * f, self.kern_dot_flops * f)

    def merge(self, other: "Costs"):
        self.flops += other.flops
        self.dot_flops += other.dot_flops
        self.bytes_upper += other.bytes_upper
        self.dot_bytes += other.dot_bytes
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.) + v
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v
        for k, v in other.bytes_by_prim.items():
            self.bytes_by_prim[k] = self.bytes_by_prim.get(k, 0.0) + v
        self.kern_dot_bytes += other.kern_dot_bytes
        self.kern_dot_flops += other.kern_dot_flops

    @property
    def link_bytes(self):
        return sum(self.collective_bytes.values())


def _nbytes(aval) -> float:
    try:
        return float(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:  # noqa: BLE001 — abstract tokens etc.
        return 0.0


def _size(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:  # noqa: BLE001
        return 0.0


def _dot_flops(eqn) -> float:
    dn = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dn
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    batch = np.prod([a.shape[i] for i in lb]) if lb else 1.0
    k = np.prod([a.shape[i] for i in lc]) if lc else 1.0
    m = np.prod([a.shape[i] for i in range(len(a.shape))
                 if i not in lc and i not in lb])
    n = np.prod([b.shape[i] for i in range(len(b.shape))
                 if i not in rc and i not in rb])
    return 2.0 * float(batch) * float(m) * float(n) * float(k)


_COLL_FACTORS = {
    "psum": lambda n: 2.0 * (n - 1) / n,
    "psum2": lambda n: 2.0 * (n - 1) / n,
    "pmax": lambda n: 2.0 * (n - 1) / n,
    "pmin": lambda n: 2.0 * (n - 1) / n,
    "all_gather": lambda n: (n - 1) / n,
    "reduce_scatter": lambda n: (n - 1) / n,
    "all_to_all": lambda n: (n - 1) / n,
    "ppermute": lambda n: 1.0,
    "pbroadcast": lambda n: 1.0,
}


def _is_jaxpr(v) -> bool:
    return (hasattr(v, "eqns") or hasattr(v, "jaxpr")) and not isinstance(
        v, (str, bytes, tuple, list, dict))


def _axis_size(eqn, mesh_shape: dict) -> int:
    names = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(names, (tuple, list)):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh_shape.get(a, 1)
    return max(n, 1)


def analyze_jaxpr(jaxpr, mesh_shape: dict, *, in_shard_map: bool = False,
                  total_devices: int = 1) -> Costs:
    c = Costs()
    # GSPMD-auto region: global shapes; approximate per-device by /devices
    frac = 1.0 if in_shard_map else 1.0 / max(total_devices, 1)
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        io_bytes = sum(_nbytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval")) + \
            sum(_nbytes(v.aval) for v in eqn.outvars)
        if prim == "dot_general":
            f = _dot_flops(eqn)
            c.flops += f * frac
            c.dot_flops += f * frac
            c.dot_bytes += io_bytes * frac
            c.bytes_upper += io_bytes * frac
            c.bytes_by_prim["dot_general"] = \
                c.bytes_by_prim.get("dot_general", 0.0) + io_bytes * frac
            try:
                a32 = all(str(v.aval.dtype) == "float32" for v in eqn.invars)
            except Exception:  # noqa: BLE001
                a32 = False
            if in_shard_map and a32:
                c.kern_dot_bytes += io_bytes
                c.kern_dot_flops += f
        elif prim == "scan":
            body = analyze_jaxpr(eqn.params["jaxpr"].jaxpr, mesh_shape,
                                 in_shard_map=in_shard_map,
                                 total_devices=total_devices)
            c.merge(body.scaled(float(eqn.params["length"])))
        elif prim == "while":
            body = analyze_jaxpr(eqn.params["body_jaxpr"].jaxpr, mesh_shape,
                                 in_shard_map=in_shard_map,
                                 total_devices=total_devices)
            c.merge(body)      # unknown trip count: counted once (unused here)
        elif prim in ("cond", "switch"):
            # expected cost over branches (uniform prior): bubble-skip conds
            # and per-layer kind dispatch each execute one branch per step
            branches = [analyze_jaxpr(b.jaxpr, mesh_shape,
                                      in_shard_map=in_shard_map,
                                      total_devices=total_devices)
                        for b in eqn.params["branches"]]
            for b in branches:
                c.merge(b.scaled(1.0 / len(branches)))
        elif prim == "shard_map":
            inner = eqn.params["jaxpr"]
            body = analyze_jaxpr(getattr(inner, "jaxpr", inner), mesh_shape,
                                 in_shard_map=True,
                                 total_devices=total_devices)
            c.merge(body)
        elif prim in _COLL_FACTORS:
            n = _axis_size(eqn, mesh_shape)
            payload = sum(_nbytes(v.aval) for v in eqn.invars
                          if hasattr(v, "aval"))
            if prim == "all_gather":
                payload = sum(_nbytes(v.aval) for v in eqn.outvars)
            c.add_coll(prim, payload * _COLL_FACTORS[prim](n), 1.0)
            c.bytes_upper += io_bytes * frac
        elif prim in ("squeeze", "reshape", "broadcast_in_dim", "transpose",
                      "copy", "expand_dims", "rev", "bitcast_convert_type"):
            pass                      # layout-only: fused / free on TPU
        elif prim in ("dynamic_update_slice", "scatter", "scatter-add",
                      "scatter_add"):
            # in-place RMW: traffic ~ the update slice, not the full operand
            upd = _nbytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else 0.0
            c.bytes_upper += 2.0 * upd * frac
            c.bytes_by_prim[prim] = c.bytes_by_prim.get(prim, 0.0) \
                + 2.0 * upd * frac
        elif prim in ("dynamic_slice", "gather", "convert_element_type"):
            out_b = sum(_nbytes(v.aval) for v in eqn.outvars)
            c.bytes_upper += out_b * frac
            c.bytes_by_prim[prim] = c.bytes_by_prim.get(prim, 0.0) \
                + out_b * frac
        elif any(_is_jaxpr(v) for v in eqn.params.values()):
            # generic call-like primitive (jit, remat, custom_vjp, ...)
            for v in eqn.params.values():
                if _is_jaxpr(v):
                    body = analyze_jaxpr(getattr(v, "jaxpr", v), mesh_shape,
                                         in_shard_map=in_shard_map,
                                         total_devices=total_devices)
                    c.merge(body)
        else:
            # elementwise / reduce / slice / gather etc.: 1 flop per output
            # element, unfused bytes upper bound
            out_sz = sum(_size(v.aval) for v in eqn.outvars)
            c.flops += out_sz * frac
            c.bytes_upper += io_bytes * frac
            c.bytes_by_prim[prim] = c.bytes_by_prim.get(prim, 0.0) \
                + io_bytes * frac
    return c


def analyze_fn(fn, args, mesh) -> Costs:
    jaxpr = jax.make_jaxpr(fn)(*args)
    mesh_shape = dict(mesh.shape)
    total = int(np.prod(list(mesh_shape.values())))
    return analyze_jaxpr(jaxpr.jaxpr, mesh_shape, total_devices=total)
