"""Comm/compute overlap benchmark — the three overlap layers, measured.

Sweeps overlap on/off and writes machine-readable ``BENCH_overlap.json``:

  runtime     threaded WSP fleet, blocking vs async push, per topology
              preset x model: wall clock, modeled comm, hidden (overlapped)
              comm. The simulated network is scaled so one wave's push costs
              about one wave's compute on the hetero preset's inter-node
              link — the regime where async push matters (comm ~ compute,
              max(c,m) vs c+m). The all-NVLink `single` preset is the
              control: with ~zero comm to hide, async push only pays its
              outbox thread-handoff overhead, so its reduction hovers
              around (or slightly below) zero — only the cross-node presets
              are expected to win.
  partitioner analytic min-max partition with real stage-boundary links,
              serial vs overlap-aware stage_time: minmax stage seconds and
              1F1B throughput.
  spmd        the skewed (software-pipelined) wave schedule vs the oracle
              schedule: loss/param identity, via the canonical subprocess
              harness (tests/pipeline_equiv_main.py, mode 'overlap').

  PYTHONPATH=src python benchmarks/overlap_bench.py [--tiny] [--out PATH]

--tiny is the CI smoke configuration (fewer waves, fewer cells).
"""
from __future__ import annotations

import argparse
import json
import os
import re
import subprocess
import sys
import time

import jax
import numpy as np

from repro.api import ClusterSpec, Engine, Plan, RunSpec, WSP
from repro.configs import ARCHS, reduced
from repro.core import wave
from repro.core.partition import (PAPER_GPUS, layer_costs, partition_minmax,
                                  pipeline_throughput)
from repro.dist.topology import ETH_1G, ETH_10G, make_topology, stage_links
from repro.models import lm
from repro.optim import make_optimizer

NUM_VW = 2
D = 2
PULL_EVERY = 4
BATCH, SEQ = 4, 32
# simulated per-wave compute (s) added to every VW: real compute on the tiny
# CPU model is ~ms, below thread-scheduling noise; this pins the
# compute:comm ratio near 1 where overlap matters most
SLOWDOWN = 0.05


def tiny_cfg(name):
    c = ARCHS[name]
    return reduced(c, num_layers=2, d_model=32, d_ff=64, vocab_size=256,
                   num_heads=2 if c.num_heads else 0,
                   num_kv_heads=2 if c.num_heads else 0,
                   head_dim=16 if c.num_heads else 0, num_microbatches=2)


def _setup(cfg):
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", 0.3)
    step = wave.build_local_wave_step(cfg, cfg.num_microbatches, opt)
    return params, opt, step


def _measure_wave_seconds(params, opt, step, reps=3):
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (BATCH, SEQ)).astype(np.int32)
    y = rng.integers(0, 256, (BATCH, SEQ)).astype(np.int32)
    st = opt.init(params)
    step(params, st, x, y)                         # warm the jit cache
    ts = []
    for _ in range(reps):
        t0 = time.monotonic()
        step(params, st, x, y)
        ts.append(time.monotonic() - t0)
    return float(np.median(ts))


def runtime_sweep(arch_names, topo_specs, waves):
    rows = []
    for name in arch_names:
        cfg = tiny_cfg(name)
        params, opt, step = _setup(cfg)
        t_comp = _measure_wave_seconds(params, opt, step) + SLOWDOWN
        push_bytes = sum(np.asarray(l).astype(np.float32).nbytes
                         for l in jax.tree.leaves(params))
        # one push ~ one wave of compute on the hetero inter-node link; the
        # same time_scale is reused for every preset of this model so fast
        # links stay fast
        ref = make_topology("hetero", NUM_VW)
        ref_cost = max(ref.p2p_cost(f"vw{i}", "ps", push_bytes)
                       for i in range(NUM_VW))
        time_scale = t_comp / ref_cost if ref_cost > 0 else 0.0
        base = Plan(cluster=ClusterSpec(num_vw=NUM_VW),
                    sync=WSP(D=D),
                    run=RunSpec(max_waves=2, batch=BATCH, seq=SEQ,
                                vocab=cfg.vocab_size))
        # throwaway run: everything (jit cache, worker threads, loaders)
        # warm before any timed cell
        Engine(base, params=params, wave_step=step, optimizer=opt).fit()
        for spec in topo_specs:
            cell = {"arch": name, "topology": spec,
                    "time_scale": time_scale,
                    "wave_compute_s": t_comp, "push_bytes": int(push_bytes)}
            for mode, async_push in (("blocking", False), ("async", True)):
                plan = base.replace(
                    cluster=ClusterSpec(num_vw=NUM_VW,
                                        topology=make_topology(spec, NUM_VW),
                                        speeds=[SLOWDOWN] * NUM_VW,
                                        time_scale=time_scale),
                    sync=WSP(D=D, pull_every=PULL_EVERY,
                             async_push=async_push),
                    run__max_waves=waves)
                rep = Engine(plan, params=params, wave_step=step,
                             optimizer=opt).fit()
                cell[mode] = {
                    "wall_s": rep.wall_s, "waves": rep.waves,
                    "comm_seconds": rep.comm_seconds,
                    "overlap_seconds": rep.overlap_seconds,
                    "push_wait_seconds": rep.push_wait_seconds,
                }
            cell["reduction_pct"] = 100.0 * (
                1.0 - cell["async"]["wall_s"] / cell["blocking"]["wall_s"])
            print(f"runtime {name:14s} {spec:8s} "
                  f"blocking={cell['blocking']['wall_s']:.2f}s "
                  f"async={cell['async']['wall_s']:.2f}s "
                  f"hidden={cell['async']['overlap_seconds']:.2f}s "
                  f"reduction={cell['reduction_pct']:.1f}%")
            rows.append(cell)
    return rows


def partitioner_sweep(arch_names, nm=4):
    """HD-style heterogeneous 4-stage fleets with Ethernet at the
    type-change boundaries (10 GbE and whimpy 1 GbE): overlap-aware
    stage_time vs serial."""
    rows = []
    fleets = {"VVQQ": [PAPER_GPUS["V"]] * 2 + [PAPER_GPUS["Q"]] * 2,
              "RRGG": [PAPER_GPUS["R"]] * 2 + [PAPER_GPUS["G"]] * 2}
    inters = {"eth10": ETH_10G, "eth1": ETH_1G}
    for name in arch_names:
        cfg = ARCHS[name]
        fl, pb, ab = layer_costs(cfg, 4096, nm * 4096)
        for (fname, devs), (iname, inter) in (
                (f, i) for f in fleets.items() for i in inters.items()):
            links = stage_links(devs, inter)
            cell = {"arch": name, "fleet": fname, "inter": iname, "nm": nm,
                    "links": [l.name for l in links]}
            for mode, overlap in (("serial", False), ("overlap", True)):
                bounds, times, ok = partition_minmax(
                    fl, ab, pb, devs, nm, links=links, overlap=overlap)
                cell[mode] = {
                    "feasible": bool(ok),
                    "bounds": bounds if ok else None,
                    "minmax_stage_s": float(max(times)) if ok else None,
                    "throughput_mb_s":
                        pipeline_throughput(times, nm) if ok else 0.0,
                }
            if cell["serial"]["feasible"] and cell["overlap"]["feasible"]:
                cell["speedup"] = (cell["overlap"]["throughput_mb_s"]
                                   / cell["serial"]["throughput_mb_s"])
                cell["cuts_moved"] = (cell["serial"]["bounds"]
                                      != cell["overlap"]["bounds"])
            def _fmt(v):
                return f"{v:.4f}s" if v is not None else "infeasible"
            print(f"partition {name:14s} {fname}/{iname} "
                  f"serial={_fmt(cell['serial']['minmax_stage_s'])} "
                  f"overlap={_fmt(cell['overlap']['minmax_stage_s'])} "
                  f"speedup={cell.get('speedup', 0):.3f}x "
                  f"cuts_moved={cell.get('cuts_moved')}")
            rows.append(cell)
    return rows


def spmd_identity(arch_name):
    """Skewed schedule vs oracle schedule on a fake multi-device mesh —
    delegated to the canonical equivalence harness
    (tests/pipeline_equiv_main.py, mode 'overlap'), the same subprocess
    tests/test_system.py drives, so there is exactly one implementation of
    the identity check."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        ":" + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    r = subprocess.run(
        [sys.executable, os.path.join(root, "tests",
                                      "pipeline_equiv_main.py"),
         arch_name, "overlap"],
        capture_output=True, text=True, timeout=1200, env=env)
    m = re.search(r"overlap_loss_diff=(\S+) overlap_param_diff=(\S+)",
                  r.stdout)
    out = {"arch": arch_name,
           "loss_identical": r.returncode == 0 and m is not None,
           "loss_diff": float(m.group(1)) if m else None,
           "param_diff": float(m.group(2)) if m else None}
    if r.returncode != 0:
        out["error"] = (r.stdout + r.stderr)[-500:]
    print(f"spmd {arch_name}: loss_identical={out['loss_identical']} "
          f"loss_diff={out['loss_diff']} param_diff={out['param_diff']}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke configuration")
    ap.add_argument("--out", default="BENCH_overlap.json")
    a = ap.parse_args()
    if a.tiny:
        archs, topos, waves = ["qwen3-0.6b"], ["single", "hetero"], 8
        part_archs = ["qwen3-0.6b"]
    else:
        archs, topos, waves = (["qwen3-0.6b", "gemma3-1b"],
                               ["single", "2node", "hetero"], 16)
        part_archs = ["qwen3-0.6b", "gemma3-1b", "granite-moe-1b-a400m"]
    doc = {
        "meta": {"mode": "tiny" if a.tiny else "full", "num_vw": NUM_VW,
                 "D": D, "pull_every": PULL_EVERY, "waves": waves,
                 "time_scale_policy":
                     "one push ~ one wave compute on hetero inter link"},
        "runtime": runtime_sweep(archs, topos, waves),
        "partitioner": partitioner_sweep(part_archs),
        "spmd": spmd_identity(archs[0]),
    }
    with open(a.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {a.out}")
    het = [r for r in doc["runtime"] if r["topology"] == "hetero"]
    for r in het:
        print(f"hetero {r['arch']}: async push cuts simulated wall clock by "
              f"{r['reduction_pct']:.1f}%")


if __name__ == "__main__":
    main()
