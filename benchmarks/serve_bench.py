"""Serving benchmark — the BENCH_serve.json baseline.

Measures, per architecture (reduced CPU configs; relative numbers are the
point, the file is a trajectory anchor per the ROADMAP):

  - prefill_ms: one batched prefill call (warm jit)
  - ms_per_token: batched greedy decode through Engine.generate()
  - batched vs sequential throughput: the same requests pushed through the
    continuous-batching Scheduler with max_batch slots vs one at a time
    (batch-of-1 Plan) — the speedup continuous batching buys

  PYTHONPATH=src python benchmarks/serve_bench.py           # full sweep
  PYTHONPATH=src python benchmarks/serve_bench.py --tiny    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "BENCH_serve.json")


def bench_arch(name: str, *, prompt_len: int, gen: int, max_batch: int,
               n_req: int):
    import numpy as np

    from repro.api import Engine, Plan, ServeSpec
    from repro.api.serving import Request, Scheduler
    from repro.configs import ARCHS, reduced

    cfg = reduced(ARCHS[name])
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (max_batch, prompt_len),
                           dtype=np.int32)

    plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=prompt_len, gen=gen,
                                          max_batch=max_batch))
    eng = Engine(plan)
    eng.generate(prompts)                        # warm the jit caches
    rep = eng.generate(prompts)                  # measured

    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                        dtype=np.int32))
            for i in range(n_req)]

    def timed_run(engine, request_batches):
        Scheduler(engine).run([r for b in request_batches for r in b])
        t0 = time.monotonic()
        toks = 0
        for batch in request_batches:
            out = Scheduler(engine).run(list(batch))
            toks += out.tokens_out
        return toks, time.monotonic() - t0, out

    b_toks, b_s, b_out = timed_run(eng, [reqs])
    one = Engine(plan.replace(serve__max_batch=1))
    s_toks, s_s, _ = timed_run(one, [[r] for r in reqs])
    assert b_toks == s_toks == n_req * gen, (b_toks, s_toks)

    return {
        "arch": cfg.name,
        "prompt_len": prompt_len, "gen": gen, "max_batch": max_batch,
        "requests": n_req,
        "prefill_ms": rep.prefill_s * 1e3,
        "ms_per_token": rep.ms_per_token(),
        "batched": {"tokens": b_toks, "wall_s": b_s,
                    "tokens_per_s": b_toks / b_s,
                    "occupancy": b_out.occupancy()},
        "sequential": {"tokens": s_toks, "wall_s": s_s,
                       "tokens_per_s": s_toks / s_s},
        "batched_vs_sequential_speedup": s_s / b_s,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one arch, short generations")
    ap.add_argument("--out", default=OUT)
    a = ap.parse_args(argv)

    if a.tiny:
        cells = [("qwen3-0.6b", dict(prompt_len=8, gen=8, max_batch=4,
                                     n_req=8))]
    else:
        cells = [(n, dict(prompt_len=24, gen=16, max_batch=4, n_req=8))
                 for n in ("qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-3b")]

    doc = {"meta": {"mode": "tiny" if a.tiny else "full",
                    "backend": "threads",
                    "note": "reduced CPU configs; trajectory anchor, not "
                            "absolute hardware numbers"},
           "runtime": []}
    for name, kw in cells:
        cell = bench_arch(name, **kw)
        doc["runtime"].append(cell)
        print(f"{cell['arch']}: prefill={cell['prefill_ms']:.1f}ms "
              f"decode={cell['ms_per_token']:.1f}ms/tok "
              f"batched={cell['batched']['tokens_per_s']:.1f}tok/s "
              f"sequential={cell['sequential']['tokens_per_s']:.1f}tok/s "
              f"speedup={cell['batched_vs_sequential_speedup']:.2f}x")
    with open(a.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {a.out}")


if __name__ == "__main__":
    main()
