"""Serving benchmark — the BENCH_serve.json baseline.

Measures, per architecture (reduced CPU configs; relative numbers are the
point, the file is a trajectory anchor per the ROADMAP):

  - prefill_ms: one batched prefill call (warm jit)
  - ms_per_token: batched greedy decode through Engine.generate()
  - batched vs sequential throughput: the same requests pushed through the
    continuous-batching Scheduler with max_batch slots vs one at a time
    (batch-of-1 Plan) — the speedup continuous batching buys
  - pages: the paged-KV accounting of the batched scheduler run (pool
    size, peak pages, mean utilization), for the contiguous-degenerate
    layout the timing runs use and for a paged pool (page_size =
    prompt_len // 2) driven by mixed per-request budgets
  - shared_prefix: identical prompts under a pool squeezed below what
    unshared admission needs — prefix sharing (repro.serve.memory) must
    admit the batch without blocking, peak strictly fewer distinct
    pages, and emit bit-identical streams (CI asserts all three)
  - cluster: one big + two whimpy replicas behind the topology-priced
    Router (repro.serve.router) vs the best single replica on the same
    mixed workload — CI enforces a >= 1.3x throughput floor and
    prefix_hit_tokens > 0 on pool-bearing families

  PYTHONPATH=src python benchmarks/serve_bench.py           # full sweep
  PYTHONPATH=src python benchmarks/serve_bench.py --tiny    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "BENCH_serve.json")


def bench_arch(name: str, *, prompt_len: int, gen: int, max_batch: int,
               n_req: int):
    import numpy as np

    from repro.api import Engine, Plan, ServeSpec
    from repro.api.serving import Request, Scheduler  # noqa: F401
    from repro.configs import ARCHS, reduced

    cfg = reduced(ARCHS[name])
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (max_batch, prompt_len),
                           dtype=np.int32)

    plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=prompt_len, gen=gen,
                                          max_batch=max_batch))
    eng = Engine(plan)
    eng.generate(prompts)                        # warm the jit caches
    rep = eng.generate(prompts)                  # measured

    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, prompt_len,
                                        dtype=np.int32))
            for i in range(n_req)]

    def timed_run(engine, request_batches, reps=3):
        """Best-of-reps wall clock (shared-CPU noise hits single runs)."""
        Scheduler(engine).run([r for b in request_batches for r in b])
        best = None
        for _ in range(reps):
            t0 = time.monotonic()
            toks = 0
            for batch in request_batches:
                out = Scheduler(engine).run(list(batch))
                toks += out.tokens_out
            dt = time.monotonic() - t0
            if best is None or dt < best[1]:
                best = (toks, dt, out)
        return best

    b_toks, b_s, b_out = timed_run(eng, [reqs])
    one = Engine(plan.replace(serve__max_batch=1))
    s_toks, s_s, _ = timed_run(one, [[r] for r in reqs])
    assert b_toks == s_toks == n_req * gen, (b_toks, s_toks)

    def page_cols(rep):
        pu = rep.page_utilization()
        return {"page_size": rep.page_size, "pages_total": rep.pages_total,
                "peak_pages": rep.peak_pages,
                "utilization": 0.0 if pu is None else pu,
                "admit_blocked": rep.admit_blocked}

    # paged pool with mixed per-request budgets: each admission allocates
    # only its own pages (page_size < prompt_len exercises real paging;
    # budgets capped at gen/2 so the mix genuinely needs less than the
    # worst-case pool)
    paged = Engine(plan.replace(serve=ServeSpec(
        prompt_len=prompt_len, gen=gen, max_batch=max_batch,
        page_size=max(1, prompt_len // 2))))
    mixed = [Request(rid=r.rid, prompt=r.prompt,
                     max_new_tokens=1 + (r.rid % max(1, gen // 2)))
             for r in reqs]
    p_out = Scheduler(paged).run(mixed)

    # shared-prefix workload: every request carries the same full prompt
    # and the pool is squeezed one page below what unshared admission
    # needs at full batch — prefix sharing must admit without blocking
    # and peak strictly below the unshared run, with identical streams.
    # page_size is chosen so the prompt ends inside a page (CoW tail).
    from repro.serve.cache import make_layout
    ps_s = max(2, prompt_len // 2 - 1)
    gen_s = max(2, gen // 4)
    lo = make_layout(max_batch, prompt_len + gen, page_size=ps_s)
    per_req = lo.pages_for(prompt_len + gen_s)
    budget = max(lo.pages_per_slot, per_req * max_batch - 1)
    common = rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)
    mk_shared = lambda: [Request(rid=i, prompt=common.copy(),
                                 max_new_tokens=gen_s)
                         for i in range(n_req)]
    sv_kw = dict(prompt_len=prompt_len, gen=gen, max_batch=max_batch,
                 page_size=ps_s, max_pages=budget)
    u_out = Scheduler(Engine(plan.replace(
        serve=ServeSpec(**sv_kw)))).run(mk_shared())
    s_out = Scheduler(Engine(plan.replace(
        serve=ServeSpec(share_prefix=True, evict=True,
                        **sv_kw)))).run(mk_shared())
    assert [r.tokens for r in s_out.requests] == \
        [r.tokens for r in u_out.requests], "sharing changed a stream"
    if s_out.pages_total:
        assert s_out.prefix_hit_tokens > 0
        assert s_out.peak_pages < u_out.peak_pages, \
            (s_out.peak_pages, u_out.peak_pages)
        assert u_out.admit_blocked > 0 and s_out.admit_blocked == 0
    shared_cell = {
        "tokens": s_out.tokens_out,
        "unshared": page_cols(u_out),
        "shared": page_cols(s_out),
        "prefix_hit_tokens": s_out.prefix_hit_tokens,
        "pages_shared": s_out.pages_shared,
        "cow_copies": s_out.cow_copies,
        "evictions": s_out.evictions,
        "preemptions": s_out.preemptions,
    }

    # scale-out cluster: one big + two whimpy replicas behind the Router
    # (repro.serve.router), priced with the 'hetero' topology, vs the best
    # single replica (the big one alone) on the same mixed workload — a
    # quarter of the requests share one full prompt so affinity has a
    # prefix to pin (prefix_hit_tokens stays 0 for pool-less families)
    from repro.api import PartitionSpec, ReplicaSpec
    from repro.serve.router import Router
    whimpy = max(1, max_batch // 2)
    csv_kw = dict(prompt_len=prompt_len, gen=gen, max_batch=max_batch,
                  page_size=max(1, prompt_len // 2), share_prefix=True,
                  evict=True)
    common = rng.integers(0, cfg.vocab_size, prompt_len, dtype=np.int32)
    cases = []
    for i in range(2 * n_req):
        if i % 4 == 0:
            p = common.copy()
        else:
            p = rng.integers(0, cfg.vocab_size,
                             int(rng.integers(2, prompt_len + 1)),
                             dtype=np.int32)
        cases.append((p, 1 + (i % gen)))
    mk_cases = lambda: [Request(rid=i, prompt=p.copy(), max_new_tokens=m)
                        for i, (p, m) in enumerate(cases)]
    want_toks = sum(m for _, m in cases)

    router = Router(plan.replace(
        serve=ServeSpec(replicas=(ReplicaSpec(max_batch=max_batch),
                                  ReplicaSpec(max_batch=whimpy),
                                  ReplicaSpec(max_batch=whimpy)),
                        **csv_kw),
        partition__data=3, cluster__topology="hetero"))
    warm = router.run(mk_cases())       # compile + per-run router counters
    assert warm.tokens_out == want_toks and warm.failed_requests == 0
    if warm.pages_total:
        assert warm.prefix_hit_tokens > 0
        assert warm.router["affinity_hits"] > 0
    # fleet timing is *modeled*: each replica rides its own node in the
    # deployment the cluster Plan describes, so fleet wall is the busiest
    # replica's wall (router reports modeled_fleet_wall_s); the single
    # bench host serializes the replica threads, and that measured host
    # wall rides along under host_wall_s for honesty
    c_host = c_fleet = None
    for _ in range(3):
        t0 = time.monotonic()
        c_out = router.run(mk_cases())
        dt = time.monotonic() - t0
        c_host = dt if c_host is None else min(c_host, dt)
        fw = c_out.router["modeled_fleet_wall_s"]
        c_fleet = fw if c_fleet is None else min(c_fleet, fw)
    single = Engine(plan.replace(serve=ServeSpec(**csv_kw)))
    sched = lambda: Scheduler(single).run(mk_cases())
    sref = sched()                       # warm
    assert sref.tokens_out == want_toks
    u_s = None
    for _ in range(3):
        t0 = time.monotonic()
        sched()
        dt = time.monotonic() - t0
        u_s = dt if u_s is None else min(u_s, dt)
    cluster_cell = {
        "replicas": [max_batch, whimpy, whimpy],
        "topology": "hetero",
        "requests": 2 * n_req,
        "tokens": c_out.tokens_out,
        "fleet_wall_s": c_fleet,
        "host_wall_s": c_host,
        "tokens_per_s": want_toks / c_fleet,
        "best_single": {"max_batch": max_batch, "wall_s": u_s,
                        "tokens_per_s": want_toks / u_s},
        "speedup_vs_best_single": u_s / c_fleet,
        "prefix_hit_tokens": warm.prefix_hit_tokens,
        "affinity_hits": warm.router["affinity_hits"],
        "dispatches": warm.router["dispatches"],
        "has_pool": bool(warm.pages_total),
        "note": "fleet wall = busiest replica (replicas model separate "
                "nodes); host_wall_s is the serialized bench-host wall",
    }

    # one *untimed* traced pass: the telemetry block (TTFT distribution,
    # admission-group accounting) never has tracing on during the timed
    # batched/sequential cells the CI speedup floor reads
    from repro.obs import Tracer
    from repro.obs.metrics import quantile_from_snapshot
    teng = Engine(plan)
    Scheduler(teng).run(list(reqs))    # warm this engine's jit untraced so
    tr = Tracer()                      # compile never lands in the TTFTs
    teng.tracer = tr
    t_out = Scheduler(teng).run(list(reqs))
    tt = t_out.telemetry.histograms.get("serve/ttft_s", {})
    telemetry = {
        "ttft_s": {"p50": quantile_from_snapshot(tt, 0.5),
                   "p99": quantile_from_snapshot(tt, 0.99),
                   "mean": t_out.mean_ttft(), "max": tt.get("max")},
        "prefill_calls": t_out.prefill_calls,
        "mean_prefill_group_s": (t_out.prefill_s / t_out.prefill_calls
                                 if t_out.prefill_calls else 0.0),
        "trace_events": len(tr),
    }

    return {
        "arch": cfg.name,
        "prompt_len": prompt_len, "gen": gen, "max_batch": max_batch,
        "requests": n_req,
        "prefill_ms": rep.prefill_s * 1e3,
        "ms_per_token": rep.ms_per_token(),
        "batched": {"tokens": b_toks, "wall_s": b_s,
                    "tokens_per_s": b_toks / b_s,
                    "occupancy": b_out.occupancy(),
                    "pages": page_cols(b_out)},
        "sequential": {"tokens": s_toks, "wall_s": s_s,
                       "tokens_per_s": s_toks / s_s},
        "batched_vs_sequential_speedup": s_s / b_s,
        "paged_mixed_budgets": {"tokens": p_out.tokens_out,
                                "pages": page_cols(p_out)},
        "shared_prefix": shared_cell,
        "cluster": cluster_cell,
        "telemetry": telemetry,
    }


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: one arch, short generations")
    ap.add_argument("--out", default=OUT)
    a = ap.parse_args(argv)

    if a.tiny:
        cells = [("qwen3-0.6b", dict(prompt_len=8, gen=8, max_batch=4,
                                     n_req=8))]
    else:
        # 8 decode slots: the batched-vs-sequential ceiling is max_batch,
        # so 4 slots would sit within noise of the 3.8x floor CI enforces
        cells = [(n, dict(prompt_len=24, gen=16, max_batch=8, n_req=16))
                 for n in ("qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-3b")]

    doc = {"meta": {"mode": "tiny" if a.tiny else "full",
                    "backend": "threads",
                    "note": "reduced CPU configs; trajectory anchor, not "
                            "absolute hardware numbers"},
           "runtime": []}
    for name, kw in cells:
        cell = bench_arch(name, **kw)
        doc["runtime"].append(cell)
        print(f"{cell['arch']}: prefill={cell['prefill_ms']:.1f}ms "
              f"decode={cell['ms_per_token']:.1f}ms/tok "
              f"batched={cell['batched']['tokens_per_s']:.1f}tok/s "
              f"sequential={cell['sequential']['tokens_per_s']:.1f}tok/s "
              f"speedup={cell['batched_vs_sequential_speedup']:.2f}x")
        sh = cell["shared_prefix"]
        print(f"  shared_prefix: peak {sh['unshared']['peak_pages']} -> "
              f"{sh['shared']['peak_pages']} pages, "
              f"hit={sh['prefix_hit_tokens']} tok "
              f"blocked {sh['unshared']['admit_blocked']} -> "
              f"{sh['shared']['admit_blocked']}")
        cl = cell["cluster"]
        print(f"  cluster {cl['replicas']}: "
              f"{cl['tokens_per_s']:.1f}tok/s vs best single "
              f"{cl['best_single']['tokens_per_s']:.1f}tok/s "
              f"({cl['speedup_vs_best_single']:.2f}x), "
              f"affinity_hits={cl['affinity_hits']} "
              f"prefix_hit={cl['prefix_hit_tokens']} tok")
    with open(a.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {a.out}")


if __name__ == "__main__":
    main()
