"""Train-preset benchmark — the BENCH_train.json baseline.

Two sections (reduced CPU configs; relative numbers are the point, the
file is a trajectory anchor per the ROADMAP):

  presets      the canonical train presets (`single_node`, `paper_hetero`,
               `bsp_baseline`) run end to end through the Engine: waves,
               wall clock (simulated for BSP's straggler-gated loop),
               steps/s, and the loss trajectory sanity (end < start).

  wsp_vs_bsp   the paper's headline, measured apples to apples: the same
               heterogeneous 4-VW fleet (per-VW slowdowns, the paper's
               V/R/G/Q topology, network time scaled so one worker's push
               costs about one wave) trained with WSP (D=2, async push —
               sync hidden under the next wave's compute) vs BSP (the
               ring all-reduce on the critical path of every wave, gated
               by the slowest worker). Both walls price modeled network
               seconds at the same time_scale: WSP's transport sleeps are
               scaled by the runtime, BSP's modeled collective seconds are
               scaled here. CI asserts hetero-WSP >= BSP steps/s.

  PYTHONPATH=src python benchmarks/train_bench.py           # full sweep
  PYTHONPATH=src python benchmarks/train_bench.py --tiny    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "BENCH_train.json")

def preset_cells(waves):
    import jax
    import numpy as np

    from repro.api import Engine, get_preset
    from repro.core import wave
    from repro.models import lm
    from repro.optim import make_optimizer

    # one prebuilt (params, optimizer, wave step) injected into every cell:
    # the presets share the tiny arch, so this compiles the jitted wave step
    # once — otherwise each cell pays its own multi-second compile inside
    # the timed fit() and the steps/s comparison measures XLA, not sync
    arch = get_preset("single_node").arch
    params, _ = lm.init_params(arch, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", 0.3)
    step = wave.build_local_wave_step(arch, arch.num_microbatches, opt)

    def engine(plan):
        return Engine(plan, params=params, wave_step=step, optimizer=opt)

    # throwaway run: jit cache, worker threads and loaders all warm
    engine(get_preset("single_node", run__max_waves=2)).fit()

    rows = []
    for name in ("single_node", "paper_hetero", "bsp_baseline"):
        plan = get_preset(name, **({"run__max_waves": waves} if waves
                                   else {}))
        t0 = time.monotonic()
        rep = engine(plan).fit()
        host_s = time.monotonic() - t0
        _, loss = rep.loss_curve()
        cell = {
            "preset": name,
            "backend": plan.run.backend,
            "sync": plan.sync.describe(),
            "num_vw": plan.cluster.num_vw,
            "waves": rep.waves,
            "wall_s": rep.wall_s,          # simulated for the BSP loop
            "host_s": host_s,
            "steps_per_s": rep.waves / rep.wall_s if rep.wall_s else 0.0,
            "first_loss": float(loss[0]),
            "final_loss": float(np.mean(loss[-4:])),
        }
        assert cell["final_loss"] < cell["first_loss"], (name, cell)
        print(f"preset {name:14s} waves={cell['waves']} "
              f"wall={cell['wall_s']:.2f}s "
              f"steps/s={cell['steps_per_s']:.2f} "
              f"loss {cell['first_loss']:.3f} -> {cell['final_loss']:.3f}")
        rows.append(cell)
    return rows


NUM_VW = 4
SLOWDOWNS = (0.02, 0.03, 0.04, 0.05)   # per-VW extra seconds/wave (hetero)


def wsp_vs_bsp(waves):
    """Same hetero fleet, same data, same model: WSP(D=2, async) vs BSP,
    both walls in the same simulated-network currency."""
    import jax
    import numpy as np

    from repro.api import BSP, ClusterSpec, Engine, Plan, RunSpec, WSP
    from repro.core import wave
    from repro.configs import ARCHS, reduced
    from repro.dist.topology import make_topology
    from repro.models import lm
    from repro.optim import make_optimizer

    cfg = reduced(ARCHS["qwen3-0.6b"], num_layers=2, d_model=32, d_ff=64,
                  vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=16,
                  num_microbatches=2)
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", 0.3)
    step = wave.build_local_wave_step(cfg, cfg.num_microbatches, opt)
    push_bytes = sum(np.asarray(l).astype(np.float32).nbytes
                     for l in jax.tree.leaves(params))
    # one worker's push ~ half a (slowed) wave on the paper topology's
    # slowest link: link contention between concurrent pushers roughly
    # doubles the effective delay, landing comm ~ compute — the regime
    # where sync placement decides throughput
    topo = make_topology("paper", NUM_VW)
    ref_cost = max(topo.p2p_cost(f"vw{i}", "ps", push_bytes)
                   for i in range(NUM_VW))
    time_scale = 0.5 * max(SLOWDOWNS) / ref_cost if ref_cost > 0 else 0.0

    def fleet(sync):
        return Plan(cluster=ClusterSpec(num_vw=NUM_VW,
                                        topology=make_topology("paper",
                                                               NUM_VW),
                                        speeds=SLOWDOWNS,
                                        time_scale=time_scale),
                    sync=sync,
                    run=RunSpec(max_waves=waves, batch=4, seq=32,
                                vocab=cfg.vocab_size))

    # warm the jit / worker threads before any timed cell
    Engine(Plan(cluster=ClusterSpec(num_vw=NUM_VW), sync=WSP(D=2),
                run=RunSpec(max_waves=2, batch=4, seq=32,
                            vocab=cfg.vocab_size)),
           params=params, wave_step=step, optimizer=opt).fit()

    out = {"arch": cfg.name, "num_vw": NUM_VW, "slowdowns": SLOWDOWNS,
           "time_scale": time_scale, "push_bytes": int(push_bytes),
           "waves": waves}
    for mode, sync in (("wsp", WSP(D=2, pull_every=4, async_push=True)),
                       ("bsp", BSP())):
        rep = Engine(fleet(sync), params=params, wave_step=step,
                     optimizer=opt).fit()
        wall = rep.wall_s
        if mode == "bsp":
            # the BSP loop's simulated clock prices the ring all-reduce in
            # unscaled modeled seconds; re-price it at the fleet's
            # time_scale so both walls speak the same currency (the WSP
            # runtime's transport sleeps are already scaled)
            wall += rep.comm_seconds * (time_scale - 1.0)
        out[mode] = {
            "wall_s": wall,
            "waves": rep.waves,
            "steps_per_s": rep.waves / wall if wall else 0.0,
            "comm_seconds": rep.comm_seconds,
            "comm_seconds_scaled": rep.comm_seconds * time_scale,
        }
        print(f"{mode} hetero fleet: waves={rep.waves} wall={wall:.2f}s "
              f"steps/s={out[mode]['steps_per_s']:.2f} "
              f"comm(scaled)={out[mode]['comm_seconds_scaled']:.2f}s")
    out["wsp_over_bsp"] = (out["wsp"]["steps_per_s"]
                           / out["bsp"]["steps_per_s"]
                           if out["bsp"]["steps_per_s"] else 0.0)
    print(f"hetero WSP/BSP throughput: {out['wsp_over_bsp']:.2f}x")
    return out


def telemetry_cell(waves):
    """One *untimed* traced pass of the paper_hetero preset: the telemetry
    block (staleness distribution vs D, pipeline bubble fraction, link
    utilization) rides in BENCH_train.json without tracing ever being on
    during the timed cells above."""
    from repro.api import Engine, get_preset
    from repro.obs import Tracer
    from repro.obs.metrics import quantile_from_snapshot

    tr = Tracer()
    plan = get_preset("paper_hetero",
                      **({"run__max_waves": waves} if waves else {}))
    rep = Engine(plan, tracer=tr).fit()
    tel = rep.telemetry
    st = tel.histograms.get("wsp/staleness", {})
    d = tel.gauges.get("wsp/D")
    assert st and st["max"] <= d, (st, d)   # the WSP gate's guarantee
    block = {
        "preset": "paper_hetero",
        "waves": rep.waves,
        "staleness": {"p50": quantile_from_snapshot(st, 0.5),
                      "p99": quantile_from_snapshot(st, 0.99),
                      "max": st["max"], "samples": st["count"], "D": d},
        "bubble_fraction": tel.bubble_fraction(),
        "link_utilization": tel.link_utilization(rep.wall_s),
        "gate_wait_s": tel.histograms.get("train/wait_s",
                                          {}).get("sum", 0.0),
        "trace_events": len(tr),
    }
    print(f"telemetry paper_hetero: staleness p50={block['staleness']['p50']}"
          f" p99={block['staleness']['p99']} max={st['max']} (D={d}) "
          f"bubble={block['bubble_fraction']:.2f}")
    return block


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: fewer waves")
    ap.add_argument("--out", default=OUT)
    a = ap.parse_args(argv)

    cells = preset_cells(8 if a.tiny else 0)   # 0 -> each preset's default
    doc = {"meta": {"mode": "tiny" if a.tiny else "full",
                    "note": "reduced CPU configs; trajectory anchor, not "
                            "absolute hardware numbers; BSP wall clock is "
                            "the simulated straggler-gated time"},
           "presets": cells,
           "wsp_vs_bsp": wsp_vs_bsp(12 if a.tiny else 16),
           "telemetry": telemetry_cell(8 if a.tiny else 0)}
    with open(a.out, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"wrote {a.out}")


if __name__ == "__main__":
    main()
