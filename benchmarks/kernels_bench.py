"""Kernel-level roofline + parity bench -> BENCH_kernels.json (committed).

Per kernel x shape cell:
  - analytic FLOPs / HBM bytes from the kernel's shape (formulas below),
    turned into roofline terms at the TPU v5e peaks benchmarks/roofline.py
    uses (197 TFLOP/s bf16, 819 GB/s HBM): t_compute, t_memory, the
    dominant term, and the compute/memory *fractions* of the bound time
    (compute_frac + memory_frac need not sum to 1 — each is its term over
    the max; the dominant one is 1.0).
  - measured wall-clock of the jnp reference path and the Pallas kernel in
    interpret mode on the host, plus their max abs error. Interpret mode
    executes the kernel body in Python, so the measured numbers are a
    *correctness* record, not a speed claim — the speed claim is the
    analytic roofline, which is what the CI schema check pins (fractions
    present for every kernel cell; missing cells fail rather than silently
    shrinking coverage).

Usage:
  PYTHONPATH=src python benchmarks/kernels_bench.py          # committed file
  PYTHONPATH=src python benchmarks/kernels_bench.py --tiny   # CI smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from roofline import HBM_BW, PEAK_FLOPS          # noqa: E402

from repro.kernels import ref as kref            # noqa: E402
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.kernels.mamba_ssd import ssd_chunked
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.rwkv6_scan import rwkv6_chunked

BYTES = 2                    # bf16 operand traffic on the deployment target

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_kernels.json")

EXPECTED_KERNELS = ("flash_attention", "flash_decode", "flash_decode_paged",
                    "rwkv6_chunked", "ssd_chunked", "grouped_matmul")


def _roofline(flops: float, bytes_: float) -> dict:
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    bound = max(t_c, t_m)
    return dict(t_compute=t_c, t_memory=t_m,
                intensity=flops / bytes_,
                dominant="compute" if t_c >= t_m else "memory",
                compute_frac=t_c / bound, memory_frac=t_m / bound)


def _time(fn, *args, reps=3):
    out = jax.block_until_ready(fn(*args))           # warmup + compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3, out


def _err(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                 - b.astype(jnp.float32))))


def _cell(kernel, shape, flops, bytes_, ref_fn, kern_fn):
    ref_ms, ref_out = _time(jax.jit(ref_fn))
    k_ms, k_out = _time(jax.jit(kern_fn))
    ref_leaf = ref_out[0] if isinstance(ref_out, tuple) else ref_out
    k_leaf = k_out[0] if isinstance(k_out, tuple) else k_out
    return dict(kernel=kernel, shape=shape, flops=flops, bytes=bytes_,
                roofline=_roofline(flops, bytes_),
                measured=dict(ref_ms=ref_ms, interpret_ms=k_ms,
                              max_abs_err=_err(ref_leaf, k_leaf)))


def bench_flash_attention(rng, tiny):
    cells = []
    # last shape crosses the roofline ridge (ai ~ (S+1)/4 > 240): the one
    # compute-bound cell in the committed file
    shapes = [(1, 4, 2, 64, 16, 0), (2, 8, 4, 256, 64, 0),
              (2, 8, 4, 256, 64, 128), (1, 8, 8, 1024, 64, 0)]
    if tiny:
        shapes = shapes[:1]
    for B, H, KV, S, hd, W in shapes:
        q = jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, KV, S, hd)), jnp.float32)
        live = S * W - W * (W - 1) // 2 if W else S * (S + 1) // 2
        flops = 4.0 * B * H * hd * live                   # qk + pv, masked
        bytes_ = BYTES * (2 * B * H * S * hd + 2 * B * KV * S * hd)
        cells.append(_cell(
            "flash_attention",
            dict(B=B, H=H, KV=KV, S=S, hd=hd, window=W),
            flops, bytes_,
            lambda q=q, k=k, v=v, W=W: kref.attention_ref(
                q, k, v, causal=True, window=W),
            lambda q=q, k=k, v=v, W=W: flash_attention_fwd(
                q, k, v, causal=True, window=W, block_q=64, block_k=64,
                interpret=True)))
    return cells


def bench_flash_decode(rng, tiny):
    cells = []
    shapes = [(2, 4, 2, 128, 16), (4, 8, 4, 1024, 64)]
    if tiny:
        shapes = shapes[:1]
    for B, H, KV, S, hd in shapes:
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, KV, S, hd)), jnp.float32)
        lens = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)
        mean_live = float(jnp.mean(lens))
        flops = 4.0 * B * H * hd * mean_live
        bytes_ = BYTES * (2 * B * KV * mean_live * hd + 2 * B * H * hd)
        cells.append(_cell(
            "flash_decode", dict(B=B, H=H, KV=KV, S=S, hd=hd),
            flops, bytes_,
            lambda q=q, k=k, v=v, lens=lens: kref.decode_ref(q, k, v, lens),
            lambda q=q, k=k, v=v, lens=lens: flash_decode(
                q, k, v, lens, block_k=128, interpret=True)))
    return cells


def bench_flash_decode_paged(rng, tiny):
    cells = []
    # groups, pages(+1 trash), page_size, B, KV, G, hd
    shapes = [(2, 8, 4, 2, 2, 2, 16), (2, 64, 16, 4, 4, 2, 64)]
    if tiny:
        shapes = shapes[:1]
    for L, P, ps, B, KV, G, hd in shapes:
        H = KV * G
        npg = P // B
        pool_k = jnp.asarray(
            rng.standard_normal((L, P + 1, ps, KV, hd)), jnp.float32)
        pool_v = jnp.asarray(
            rng.standard_normal((L, P + 1, ps, KV, hd)), jnp.float32)
        q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
        tab = jnp.asarray(
            rng.permutation(P)[:B * npg].reshape(B, npg), jnp.int32)
        lens = jnp.asarray(rng.integers(1, npg * ps + 1, B), jnp.int32)
        mean_live = float(jnp.mean(lens))
        flops = 4.0 * B * H * hd * mean_live
        # the fused walk reads only live pages; the gather baseline would
        # read (and write!) the full [B, npg*ps] view
        bytes_ = BYTES * (2 * B * KV * mean_live * hd + 2 * B * H * hd) \
            + 4 * B * npg
        cells.append(_cell(
            "flash_decode_paged",
            dict(groups=L, pages=P, page_size=ps, B=B, KV=KV, G=G, hd=hd),
            flops, bytes_,
            lambda q=q, pk=pool_k, pv=pool_v, t=tab, l=lens:
                kref.decode_paged_ref(q, pk, pv, t, l, layer=1),
            lambda q=q, pk=pool_k, pv=pool_v, t=tab, l=lens:
                flash_decode_paged(q, pk, pv, t, l, layer=1,
                                   interpret=True)))
    return cells


def bench_rwkv6(rng, tiny):
    cells = []
    shapes = [(1, 2, 32, 16, 16), (2, 4, 256, 64, 16)]
    if tiny:
        shapes = shapes[:1]
    for B, H, S, hd, C in shapes:
        r, k, v = (0.5 * jnp.asarray(rng.standard_normal((B, H, S, hd)),
                                     jnp.float32) for _ in range(3))
        # clip the log-decay like tests/test_kernels.py: per-step decay
        # below exp(-exp(1.386)) underflows the chunked cumulative products
        w = -jnp.exp(jnp.clip(
            jnp.asarray(rng.standard_normal((B, H, S, hd)), jnp.float32),
            -8.0, 1.386))
        u = jnp.asarray(rng.standard_normal((H, hd)), jnp.float32)
        # intra-chunk scores/y + inter-chunk state read + state update
        flops = 4.0 * B * H * S * C * hd + 6.0 * B * H * S * hd * hd
        bytes_ = 4 * (5 * B * H * S * hd + 2 * B * H * (S // C) * hd * hd)
        cells.append(_cell(
            "rwkv6_chunked", dict(B=B, H=H, S=S, hd=hd, chunk=C),
            flops, bytes_,
            lambda r=r, k=k, v=v, w=w, u=u: kref.rwkv6_ref(r, k, v, w, u),
            lambda r=r, k=k, v=v, w=w, u=u, C=C: rwkv6_chunked(
                r, k, v, w, u, chunk=C, interpret=True)))
    return cells


def bench_ssd(rng, tiny):
    cells = []
    shapes = [(1, 2, 64, 16, 16, 32), (2, 4, 256, 64, 64, 64)]
    if tiny:
        shapes = shapes[:1]
    for B, H, S, P, N, C in shapes:
        x = jnp.asarray(rng.standard_normal((B, H, S, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, H, S)), jnp.float32)
        B_ = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
        C_ = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
        a = -jnp.exp(jnp.asarray(rng.standard_normal(H), jnp.float32))
        flops = 2.0 * B * H * S * C * (N + P) + 4.0 * B * H * S * N * P
        bytes_ = 4 * (2 * B * H * S * P + 2 * B * S * N + B * H * S
                      + 2 * B * H * (S // C) * N * P)
        cells.append(_cell(
            "ssd_chunked", dict(B=B, H=H, S=S, P=P, N=N, chunk=C),
            flops, bytes_,
            lambda x=x, dt=dt, B_=B_, C_=C_, a=a: kref.ssd_ref(
                x, dt, B_, C_, a),
            lambda x=x, dt=dt, B_=B_, C_=C_, a=a, C=C: ssd_chunked(
                x, dt, B_, C_, a, chunk=C, interpret=True)))
    return cells


def bench_gmm(rng, tiny):
    cells = []
    shapes = [(4, 32, 32, 64), (8, 128, 128, 256)]
    if tiny:
        shapes = shapes[:1]
    for E, Cp, d, f in shapes:
        x = jnp.asarray(rng.standard_normal((E, Cp, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((E, d, f)), jnp.float32)
        flops = 2.0 * E * Cp * d * f
        bytes_ = BYTES * (E * Cp * d + E * d * f + E * Cp * f)
        cells.append(_cell(
            "grouped_matmul", dict(E=E, C=Cp, d=d, f=f),
            flops, bytes_,
            lambda x=x, w=w: kref.gmm_ref(x, w),
            lambda x=x, w=w: grouped_matmul(x, w, interpret=True)))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="one small shape per kernel (CI smoke)")
    ap.add_argument("--out", default=OUT)
    a = ap.parse_args(argv)
    rng = np.random.default_rng(0)
    cells = []
    for bench in (bench_flash_attention, bench_flash_decode,
                  bench_flash_decode_paged, bench_rwkv6, bench_ssd,
                  bench_gmm):
        cells.extend(bench(rng, a.tiny))
    doc = dict(meta=dict(mode="tiny" if a.tiny else "full",
                         peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW,
                         dtype_bytes=BYTES,
                         kernels=list(EXPECTED_KERNELS)),
               kernels=cells)
    missing = set(EXPECTED_KERNELS) - {c["kernel"] for c in cells}
    assert not missing, f"bench produced no cells for {sorted(missing)}"
    with open(a.out, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    for c in cells:
        r = c["roofline"]
        m = c["measured"]
        print(f"{c['kernel']:20s} {str(c['shape']):58s} "
              f"dom={r['dominant']:7s} cf={r['compute_frac']:.2f} "
              f"mf={r['memory_frac']:.2f} ai={r['intensity']:7.1f} "
              f"ref={m['ref_ms']:7.1f}ms interp={m['interpret_ms']:8.1f}ms "
              f"err={m['max_abs_err']:.2e}")
    print(f"wrote {os.path.normpath(a.out)} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
