"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:
  compute term    = per-device FLOPs / 197 TFLOP/s   (bf16 peak, TPU v5e)
  memory term     = per-device HBM bytes / 819 GB/s
  collective term = per-device link bytes / 50 GB/s/link

FLOPs/bytes come from the trip-count-aware jaxpr analysis recorded by the
dry-run (XLA's cost_analysis counts while bodies once — see
benchmarks/jaxpr_analysis.py); collective bytes use ring-model factors. The
memory term is bracketed: `mem_hi` assumes no fusion (sum of every op's
in+out), `mem_lo` counts matmul operands/outputs only; the truth lies between
and the dominant-term call uses the geometric mean.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
CHIPS = 256

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def model_flops(rec: dict) -> float:
    """Useful FLOPs per step: 6*N_active*D (train) / 2*N_active*D (fwd)."""
    n = rec["active_params"]
    shape = rec["shape"]
    if shape == "train_4k":
        return 6.0 * n * 256 * 4096
    if shape == "prefill_32k":
        return 2.0 * n * 32 * 32768
    if shape == "decode_32k":
        return 2.0 * n * 128
    return 2.0 * n * 1


def load_cells(pod: str = "pod1") -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(ART, f"*__{pod}.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


SLICE_PRIMS = ("dynamic_slice", "gather", "dynamic_update_slice", "scatter",
               "convert_element_type", "scatter-add", "scatter_add")


def roofline_row(rec: dict) -> dict | None:
    if rec.get("skipped") or not rec.get("ok"):
        return None
    f = rec["trace_flops"]
    t_comp = f / PEAK_FLOPS
    t_mem_hi = rec["trace_bytes_upper"] / HBM_BW
    by_prim = rec.get("trace_bytes_by_prim", {})
    slice_bytes = sum(by_prim.get(p_, 0.0) for p_ in SLICE_PRIMS)
    # fused estimate: matmul traffic + slice/cache/convert traffic (the terms
    # XLA cannot fuse away); elementwise chains are assumed fused
    t_mem_lo = (rec["trace_dot_bytes"] + slice_bytes) / HBM_BW
    t_mem = t_mem_lo
    # kernelized scenario: the Pallas flash/SSM kernels (validated in
    # tests/test_kernels.py) keep f32 score/state tiles VMEM-resident and
    # skip dead causal/window tiles on TPU
    kb = rec.get("trace_kern_dot_bytes", 0.0)
    kf = rec.get("trace_kern_dot_flops", 0.0)
    t_mem_kern = max(t_mem_lo - kb / HBM_BW, 0.0)
    t_comp_kern = max(t_comp - 0.45 * kf / PEAK_FLOPS, 0.0) \
        if rec.get("causal_skip", True) else t_comp
    t_coll = rec["trace_link_bytes"] / LINK_BW
    mf = model_flops(rec)
    useful = mf / (f * CHIPS) if f else 0.0
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    t_bound = max(terms.values())
    # roofline fraction: useful-FLOPs time / bound time
    t_useful = (mf / CHIPS) / PEAK_FLOPS
    frac = t_useful / t_bound if t_bound else 0.0
    hints = {
        "compute": "cut non-useful FLOPs (bubble ticks, masked causal tiles, "
                   "remat recompute, padded slots)",
        "memory": "fuse/shrink activation traffic (larger microbatches, "
                  "kernel fusion, bf16 residuals)",
        "collective": "reduce sync bytes (wave-level sync already /Nm; "
                      "repro.dist has hierarchical pod-local reduce + grad "
                      "compression — see benchmarks/comm_model.py; next: "
                      "overlap ppermute with compute)",
    }
    terms_k = {"compute": t_comp_kern, "memory": t_mem_kern,
               "collective": t_coll}
    t_bound_k = max(terms_k.values())
    frac_kern = t_useful / t_bound_k if t_bound_k else 0.0
    return dict(cell=rec["cell"], arch=rec["arch"], shape=rec["shape"],
                t_compute=t_comp, t_memory=t_mem, t_mem_lo=t_mem_lo,
                t_mem_hi=t_mem_hi, t_collective=t_coll, dominant=dom,
                t_compute_kern=t_comp_kern, t_memory_kern=t_mem_kern,
                dominant_kern=max(terms_k, key=terms_k.get),
                roofline_frac_kern=frac_kern,
                model_flops=mf, hlo_flops_dev=f, useful_ratio=useful,
                roofline_frac=frac, hint=hints[dom],
                stages=rec.get("stages"), tp=rec.get("tp"),
                nm=rec.get("nm"))


def table(pod: str = "pod1") -> list[dict]:
    rows = []
    for rec in load_cells(pod):
        r = roofline_row(rec)
        if r:
            rows.append(r)
    return rows


def render_markdown(rows: list[dict]) -> str:
    hdr = ("| cell | compute s | memory s (fused/unfused) | collective s "
           "| dominant | MODEL/HLO | frac | frac (Pallas) |\n"
           "|---|---|---|---|---|---|---|---|\n")
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])):
        lines.append(
            f"| {r['arch']}×{r['shape']} | {r['t_compute']:.3e} "
            f"| {r['t_mem_lo']:.2e}/{r['t_mem_hi']:.2e} "
            f"| {r['t_collective']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.3f} | {r['roofline_frac']:.3f} "
            f"| {r['roofline_frac_kern']:.3f} |\n")
    return "".join(lines)


def main():
    rows = table()
    print(f"{'cell':46s} {'compute':>10s} {'memory':>10s} {'coll':>10s} "
          f"{'dom':>10s} {'frac':>6s} {'frac_kern':>9s}")
    for r in sorted(rows, key=lambda x: x["roofline_frac"]):
        print(f"{r['cell']:46s} {r['t_compute']:10.3e} {r['t_memory']:10.3e} "
              f"{r['t_collective']:10.3e} {r['dominant']:>10s} "
              f"{r['roofline_frac']:6.3f} {r['roofline_frac_kern']:9.3f}")
    out = os.path.join(ART, "..", "roofline.md")
    with open(out, "w") as f:
        f.write(render_markdown(rows))
    print(f"\nwrote {out} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
