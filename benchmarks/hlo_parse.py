"""Parse collective traffic out of (SPMD-partitioned, per-device) HLO text.

cost_analysis() has no collective-bytes entry, so the roofline's collective
term is derived here: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op's per-partition shape bytes, bucketed by op.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
         "collective-permute")
_LINE_RE = re.compile(
    r"=\s*(\([^=]*?\)|\S+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {op: {'bytes': per-device payload bytes, 'count': n}, ...}.

    '-start' variants are counted once ('-done' carries no new payload).
    """
    out: dict[str, dict] = defaultdict(lambda: {"bytes": 0, "count": 0})
    for m in _LINE_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue
        b = _shape_bytes(shape_txt)
        out[op]["bytes"] += b
        out[op]["count"] += 1
    return dict(out)


def link_bytes(coll: dict) -> float:
    """Per-device bytes actually crossing links, with ring-algorithm factors:
    all-reduce moves ~2x payload, all-gather/reduce-scatter ~1x (payload is
    already the full gathered shape / pre-scatter shape), permute 1x."""
    total = 0.0
    for op, rec in coll.items():
        f = 2.0 if op == "all-reduce" else 1.0
        total += f * rec["bytes"]
    return total
