"""Hillclimb driver: rebuild one (arch x shape) cell with config overrides and
report the three roofline terms + byte/collective attribution — the
hypothesis -> change -> measure loop of EXPERIMENTS.md §Perf.

The analysis is trace-based (jaxpr; seconds, not minutes), so iteration is
cheap; winning configs are then re-verified with a full 512-device compile via
repro.launch.dryrun.

  PYTHONPATH=src:. python -m benchmarks.perf_cell --arch qwen3-0.6b \
      --shape train_4k --set num_microbatches=16 --set remat=False
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json

import jax

from benchmarks.jaxpr_analysis import analyze_fn
from benchmarks.roofline import PEAK_FLOPS, HBM_BW, LINK_BW, CHIPS, \
    model_flops


def analyze_cell(arch_name, shape_name, overrides=None, run_overrides=None,
                 multi_pod=False):
    import repro.launch.dryrun as dr
    from repro.configs import ARCHS, SHAPES, RunConfig

    cfg = ARCHS[arch_name]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    # monkey-patch the registry entry so build_cell picks up the override
    old = ARCHS[arch_name]
    ARCHS[arch_name] = cfg
    try:
        fn, args, mesh, run = dr.build_cell(arch_name, shape_name, multi_pod,
                                            run_overrides=run_overrides)
        with mesh:
            jc = analyze_fn(fn, args, mesh)
    finally:
        ARCHS[arch_name] = old
    rec = {"active_params": cfg.active_param_count(), "shape": shape_name}
    mf = model_flops(rec)
    t_comp = jc.flops / PEAK_FLOPS
    slice_primes = ("dynamic_slice", "gather", "dynamic_update_slice",
                    "scatter", "convert_element_type")
    slice_b = sum(jc.bytes_by_prim.get(p_, 0.0) for p_ in slice_primes)
    t_mem_lo = (jc.dot_bytes + slice_b) / HBM_BW
    t_mem_hi = jc.bytes_upper / HBM_BW
    t_mem = t_mem_lo
    t_mem_kern = max(t_mem_lo - jc.kern_dot_bytes / HBM_BW, 0.0)
    t_comp_kern = max(t_comp - 0.45 * jc.kern_dot_flops / PEAK_FLOPS, 0.0)
    t_coll = jc.link_bytes / LINK_BW
    return dict(
        t_compute=t_comp, t_memory=t_mem, t_mem_lo=t_mem_lo,
        t_mem_hi=t_mem_hi, t_collective=t_coll,
        dominant=max((("compute", t_comp), ("memory", t_mem),
                      ("collective", t_coll)), key=lambda kv: kv[1])[0],
        useful=mf / (jc.flops * CHIPS) if jc.flops else 0.0,
        roofline_frac=(mf / CHIPS / PEAK_FLOPS) /
        max(t_comp, t_mem, t_coll),
        t_memory_kern=t_mem_kern, t_compute_kern=t_comp_kern,
        roofline_frac_kern=(mf / CHIPS / PEAK_FLOPS) /
        max(t_comp_kern, t_mem_kern, t_coll),
        flops=jc.flops, dot_flops=jc.dot_flops,
        bytes_by_prim=dict(sorted(jc.bytes_by_prim.items(),
                                  key=lambda kv: -kv[1])[:12]),
        collectives=jc.collective_bytes,
    )


def _parse_val(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("True", "False"):
        return v == "True"
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override key=value")
    ap.add_argument("--run-set", action="append", default=[],
                    help="RunConfig override key=value")
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()
    over = dict(kv.split("=", 1) for kv in a.set)
    over = {k: _parse_val(v) for k, v in over.items()}
    rover = dict(kv.split("=", 1) for kv in a.run_set)
    rover = {k: _parse_val(v) for k, v in rover.items()}
    res = analyze_cell(a.arch, a.shape, over or None, rover or None,
                       a.multi_pod)
    print(json.dumps(res, indent=1, default=float))


if __name__ == "__main__":
    main()
