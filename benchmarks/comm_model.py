"""Communication model sweep over the repro.dist layer.

For each topology x message size, price a flat ring all-reduce against the
hierarchical pod-local-then-cross-pod reduce, and show the wire savings of
the gradient codecs — the analytic companion to roofline.py's collective
hint and the WSP-vs-BSP network experiments.

  PYTHONPATH=src python benchmarks/comm_model.py
"""
from __future__ import annotations

import numpy as np

from repro.dist.compression import (ErrorFeedbackCompressor,
                                    Int8StochasticQuantizer)
from repro.dist.topology import make_topology

SIZES_MB = (1, 16, 256, 1024)
TOPOS = ("single", "2node", "4node", "4node:ib", "hetero-2node", "paper")
NUM_VW = 8


def collective_table():
    print(f"{'topology':14s} {'msg':>7s} {'ring s':>10s} {'hier s':>10s} "
          f"{'hier/ring':>9s}")
    for spec in TOPOS:
        topo = make_topology(spec, NUM_VW)
        ws = topo.worker_names()
        for mb in SIZES_MB:
            nbytes = mb * 1e6
            ring = topo.ring_allreduce_cost(ws, nbytes)
            hier = topo.hierarchical_allreduce_cost(ws, nbytes)
            ratio = hier / ring if ring else float("nan")
            print(f"{spec:14s} {mb:5d}MB {ring:10.4f} {hier:10.4f} "
                  f"{ratio:9.2f}")
        print()


def codec_table():
    print(f"{'codec':14s} {'dense':>9s} {'wire':>9s} {'ratio':>6s}")
    rng = np.random.default_rng(0)
    g = rng.normal(size=1_000_000).astype(np.float32)
    for name, codec in (("topk:0.01", ErrorFeedbackCompressor(0.01)),
                        ("topk:0.1", ErrorFeedbackCompressor(0.1)),
                        ("int8", Int8StochasticQuantizer())):
        idx, vals = codec.compress("bench", g)
        wire = codec.wire_bytes(idx, vals)
        print(f"{name:14s} {g.nbytes/1e6:7.1f}MB {wire/1e6:7.1f}MB "
              f"{wire/g.nbytes:6.3f}")


def main():
    print("== collective cost model (alpha-beta, slowest-hop ring) ==")
    collective_table()
    print("== gradient codec wire bytes (1M float32 params) ==")
    codec_table()


if __name__ == "__main__":
    main()
