"""One benchmark per paper table/figure (HetPipe, ATC'20).

Analytic pieces use the same partitioner/allocator the system uses on real
device profiles (Table 1's GPUs); convergence/wait pieces run the real
threaded WSP runtime on a reduced LM (the paper's CNNs don't fit a 1-core CPU
budget — the adaptation is recorded in DESIGN.md/EXPERIMENTS.md).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.core.allocation import Node, allocate, vw_throughputs, \
    straggler_report
from repro.core.partition import (PAPER_GPUS, partition_minmax,
                                  pipeline_throughput,
                                  max_concurrent_minibatches)
from repro.core.wave import build_local_wave_step
from repro.models import lm
from repro.models.cnn import PAPER_MODELS
from repro.optim import make_optimizer
from repro.api import BSP, ClusterSpec, Engine, Plan, RunSpec, WSP

NODES = [Node(PAPER_GPUS[c], 4) for c in "VRGQ"]


def fig3_nm_sweep():
    """Paper Fig. 3: single-VW normalized throughput vs Nm (per allocation)."""
    out = []
    for model, costs_fn in PAPER_MODELS.items():
        fl, pb, ab = costs_fn(batch=32)
        for vw_name, vw in (("VVVV", [PAPER_GPUS["V"]] * 4),
                            ("VRGQ", [PAPER_GPUS[c] for c in "VRGQ"]),
                            ("QQQQ", [PAPER_GPUS["Q"]] * 4)):
            base = None
            for nm in (1, 2, 4, 8):
                res = partition_minmax(fl, ab, pb, vw, nm)
                if not res[2]:
                    break
                thr = pipeline_throughput(res[1], nm, "1f1b") * 32
                base = base or thr
                out.append((f"fig3/{model}/{vw_name}/nm{nm}",
                            1e6 / thr, thr / base))
    return out


def fig4_allocation_policies():
    """Paper Fig. 4: DP throughput under NP/ED/HD vs AllReduce-BSP."""
    out = []
    for model, costs_fn in PAPER_MODELS.items():
        fl, pb, ab = costs_fn(batch=32)

        class _CostCfg:           # adapter: allocator wants an arch-like cfg
            @staticmethod
            def costs():
                return fl, pb, ab
        for pol in ("NP", "ED", "HD"):
            vws = allocate(NODES, pol)
            ths = []
            for vw in vws:
                res = partition_minmax(fl, ab, pb, vw, nm=4)
                ths.append(pipeline_throughput(res[1], 4, "1f1b") * 32
                           if res[2] else 0.0)
            rep = straggler_report(np.array(ths))
            # WSP lets each VW run at its own rate; BSP gates on the slowest
            out.append((f"fig4/{model}/{pol}/wsp", 0.0, rep["wsp_rate"]))
            out.append((f"fig4/{model}/{pol}/bsp", 0.0, rep["bsp_rate"]))
    return out


def table4_whimpy_scaling():
    """Paper Table 4: throughput as whimpy GPUs are added (V -> VR -> VRQ ->
    VRQG), HetPipe(ED-style) vs data-parallel baseline."""
    out = []
    adds = [("4[V]", "V"), ("8[VR]", "VR"), ("12[VRQ]", "VRQ"),
            ("16[VRQG]", "VRQG")]
    for model, costs_fn in PAPER_MODELS.items():
        fl, pb, ab = costs_fn(batch=32)
        for label, types in adds:
            gpus = [PAPER_GPUS[c] for c in types for _ in range(4)]
            n_vw = max(1, len(gpus) // 4)
            vws = [sorted(gpus[i::n_vw], key=lambda g: -g.tflops)
                   for i in range(n_vw)]
            ths = []
            for vw in vws:
                res = partition_minmax(fl, ab, pb, vw, nm=4)
                ths.append(pipeline_throughput(res[1], 4, "1f1b") * 32
                           if res[2] else 0.0)
            # baseline: sync DP over single GPUs that can fit the model
            dp_fit = [g for g in gpus if pb.sum() * 4.5 <= g.mem_gb * 1e9]
            bsp = (len(dp_fit) * 32 /
                   (fl.sum() / min(g.eff_flops for g in dp_fit))
                   if dp_fit else 0.0)
            out.append((f"table4/{model}/{label}/hetpipe", 0.0,
                        float(np.sum(ths))))
            out.append((f"table4/{model}/{label}/dp_baseline", 0.0, bsp))
    return out


_CFG = None


def _reduced_cfg():
    global _CFG
    if _CFG is None:
        _CFG = reduced(ARCHS["qwen3-0.6b"], num_layers=2, d_model=32,
                       d_ff=64, vocab_size=256, num_heads=2, num_kv_heads=2,
                       head_dim=16, num_microbatches=2)
    return _CFG


def fig5_6_convergence(max_waves: int = 14):
    """Paper Figs. 5/6: loss-vs-wallclock for BSP-AllReduce vs WSP D=0/4/32
    with a simulated straggler (the heterogeneous-cluster effect)."""
    cfg = _reduced_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", 0.3)
    step = build_local_wave_step(cfg, cfg.num_microbatches, opt)
    speeds = [0.0, 0.08]                      # one straggling VW
    base = Plan(cluster=ClusterSpec(num_vw=2, speeds=speeds), sync=BSP(),
                run=RunSpec(max_waves=max_waves, batch=8, seq=32,
                            vocab=cfg.vocab_size))
    out = []
    t0 = time.time()
    rep = Engine(base, params=params, wave_step=step, optimizer=opt).fit()
    xs, ys = rep.loss_curve()
    out.append(("fig5/bsp_allreduce/final_loss", (time.time() - t0) * 1e6,
                float(np.mean(ys[-6:]))))
    for D in (0, 4, 32):
        t0 = time.time()
        rep = Engine(base.replace(sync=WSP(D=D)), params=params,
                     wave_step=step, optimizer=opt).fit()
        xs, ys = rep.loss_curve()
        out.append((f"fig6/wsp_D{D}/final_loss", (time.time() - t0) * 1e6,
                    float(np.mean(ys[-6:]))))
    return out


def sec84_wait_time(max_waves: int = 10):
    """Paper Sec. 8.4: average VW wait time shrinks as D grows."""
    cfg = _reduced_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", 0.3)
    step = build_local_wave_step(cfg, cfg.num_microbatches, opt)
    waits = {}
    for D in (0, 4):
        plan = Plan(cluster=ClusterSpec(num_vw=2, speeds=[0.0, 0.06]),
                    sync=WSP(D=D),
                    run=RunSpec(max_waves=max_waves, batch=8, seq=32,
                                vocab=cfg.vocab_size))
        rep = Engine(plan, params=params, wave_step=step,
                     optimizer=opt).fit()
        waits[D] = float(np.mean(list(rep.wait_seconds.values())))
    ratio = waits[4] / max(waits[0], 1e-9)
    return [("sec84/wait_D4_over_D0", 0.0, ratio)]


def wave_sync_comm_saving():
    """WSP's core trick: pushes per wave instead of per minibatch => bytes /
    Nm. Measured from the real PS byte counters."""
    cfg = _reduced_cfg()
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", 0.3)
    nm = cfg.num_microbatches
    step = build_local_wave_step(cfg, nm, opt)
    plan = Plan(cluster=ClusterSpec(num_vw=2), sync=WSP(D=0),
                run=RunSpec(max_waves=6, batch=8, seq=32,
                            vocab=cfg.vocab_size))
    rep = Engine(plan, params=params, wave_step=step, optimizer=opt).fit()
    per_minibatch_bytes = rep.bytes_pushed * nm   # counterfactual
    return [("wsp/comm_saving_factor", 0.0,
             per_minibatch_bytes / max(rep.bytes_pushed, 1))]
