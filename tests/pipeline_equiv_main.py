"""Subprocess body for pipeline-vs-oracle equivalence (needs 8 fake devices,
so it must own the process — XLA device count is locked at first jax import).

Run: XLA_FLAGS=... python tests/pipeline_equiv_main.py <arch> [decode]
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax                                   # noqa: E402
import jax.numpy as jnp                      # noqa: E402
import numpy as np                           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.compat import set_mesh                # noqa: E402
from repro.configs import ARCHS, reduced, RunConfig, ShapeConfig  # noqa: E402
from repro.core import wave                  # noqa: E402
from repro.models import lm                  # noqa: E402
from repro.optim import make_optimizer       # noqa: E402


def main(arch_name: str, mode: str = "train") -> int:
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((2, 2, 2), ("data", "stage", "tp"))
    key = jax.random.PRNGKey(0)
    over = {"capacity_factor": 8.0} if ARCHS[arch_name].num_experts else {}
    cfg = reduced(ARCHS[arch_name], stages=2, tp=2, num_layers=4,
                  num_microbatches=2, remat=True, **over)
    params, pspecs = lm.init_params(cfg, key)

    if mode == "overlap":
        # skewed (comm/compute-overlapped) schedule vs the oracle schedule:
        # one train step each from identical state must agree exactly
        shape = ShapeConfig("tiny", 32, 8, "train")
        B, S = shape.global_batch, shape.seq_len
        if cfg.frontend != "none":
            inputs = 0.02 * jax.random.normal(key, (B, S, cfg.d_model))
        else:
            inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                        dtype=jnp.int32)
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                    dtype=jnp.int32)
        opt = make_optimizer("sgd", 0.1)
        results = {}
        for overlap in (False, True):
            run = RunConfig(arch=cfg, shape=shape, optimizer="sgd", lr=0.1,
                            compute_dtype="float32", loss_chunk=16,
                            overlap=overlap)
            step, _ = wave.build_train_step(run, mesh)
            with set_mesh(mesh):
                p_sh = jax.device_put(params, jax.tree.map(
                    lambda s: NamedSharding(mesh, s), pspecs,
                    is_leaf=lambda x: isinstance(x, P)))
                new_p, _, metrics = jax.jit(step)(
                    p_sh, opt.init(params),
                    {"inputs": inputs, "labels": labels})
            results[overlap] = (jax.tree.map(np.asarray, new_p),
                                float(metrics["loss"]))
        ld = abs(results[True][1] - results[False][1])
        md = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(np.max(np.abs(a - b))),
            results[True][0], results[False][0])))
        print(f"overlap_loss_diff={ld:.3e} overlap_param_diff={md:.3e}")
        assert ld == 0.0, ld          # same compute per microbatch, same order
        assert md < 1e-6, md
        return 0

    if mode == "train":
        shape = ShapeConfig("tiny", 32, 8, "train")
        run = RunConfig(arch=cfg, shape=shape, optimizer="sgd", lr=0.1,
                        compute_dtype="float32", loss_chunk=16)
        B, S = shape.global_batch, shape.seq_len
        if cfg.frontend != "none":
            inputs = 0.02 * jax.random.normal(key, (B, S, cfg.d_model))
        else:
            inputs = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                        dtype=jnp.int32)
        labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                    dtype=jnp.int32)
        step, _ = wave.build_train_step(run, mesh)
        opt = make_optimizer("sgd", 0.1)
        with set_mesh(mesh):
            p_sh = jax.device_put(params, jax.tree.map(
                lambda s: NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P)))
            new_p, _, metrics = jax.jit(step)(
                p_sh, opt.init(params), {"inputs": inputs, "labels": labels})
        local = wave.build_local_wave_step(cfg, 4, opt)
        deltas, _, loss_local = local(params, opt.init(params), inputs,
                                      labels)
        p_local = jax.tree.map(jnp.add, params, deltas)
        md = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), new_p, p_local)))
        print(f"max_param_diff={md:.3e}")
        assert md < 1e-4, md  # bf16 CE matmul epsilon
        return 0

    # decode equivalence: pipelined decode_step (both schedules) == reference
    shape = ShapeConfig("tinydec", 32, 16, "decode")
    B, S = shape.global_batch, shape.seq_len
    if cfg.frontend != "none":
        full = 0.02 * jax.random.normal(key, (B, S, cfg.d_model))
    else:
        full = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                  dtype=jnp.int32)
    PRE = S - 1
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    _, cache, _ = lm.forward_ref(cfg, params, full[:, :PRE], mode="prefill",
                                 cache=cache)
    hd_ref, _, _ = lm.forward_ref(
        cfg, params,
        full[:, PRE:], mode="decode",
        cache=jax.tree.map(lambda a: a.copy(), cache), pos=jnp.int32(PRE))
    ref_logits = lm.logits_ref(cfg, params, hd_ref)
    by_sched = {}
    for overlap in (False, True):
        run_o = RunConfig(arch=cfg, shape=shape, compute_dtype="float32",
                          overlap=overlap)
        step, pspecs2, cspecs = wave.build_decode_step(run_o, mesh)
        with set_mesh(mesh):
            p_sh = jax.device_put(params, jax.tree.map(
                lambda s: NamedSharding(mesh, s), pspecs,
                is_leaf=lambda x: isinstance(x, P)))
            logits, _ = jax.jit(step)(p_sh, {
                "inputs": full[:, PRE:],
                "cache": jax.tree.map(lambda a: a.copy(), cache),
                "pos": jnp.int32(PRE)})
        by_sched[overlap] = logits
    md = float(jnp.max(jnp.abs(by_sched[False] - ref_logits)))
    od = float(jnp.max(jnp.abs(by_sched[True] - by_sched[False])))
    print(f"decode_logits_diff={md:.3e} decode_overlap_diff={od:.3e}")
    assert md < 1e-3, md
    assert od == 0.0, od   # skewed serve schedule identical to the oracle
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1], sys.argv[2] if len(sys.argv) > 2 else "train"))
