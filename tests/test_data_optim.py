"""Data pipeline determinism/resume + optimizer + compression + allocation."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.allocation import Node, allocate, vw_throughputs, \
    straggler_report
from repro.core.partition import PAPER_GPUS
from repro.configs import ARCHS
from repro.data.pipeline import MarkovLM, ShardedLoader
from repro.dist.compression import ErrorFeedbackCompressor, topk_compress, \
    topk_decompress
from repro.optim import make_optimizer


def test_loader_deterministic_and_resumable():
    src = MarkovLM(256, seed=3)
    a = ShardedLoader(src, 4, 16, 0, 2, seed=5)
    b = ShardedLoader(src, 4, 16, 0, 2, seed=5)
    for _ in range(3):
        xa, ya = a.next()
        xb, yb = b.next()
        np.testing.assert_array_equal(xa, xb)
    # resume from state_dict reproduces the continuation exactly
    sd = a.state_dict()
    x4, _ = a.next()
    c = ShardedLoader(src, 4, 16, 0, 2, seed=5)
    c.load_state_dict(sd)
    x4c, _ = c.next()
    np.testing.assert_array_equal(x4, x4c)


def test_loader_shards_disjoint():
    src = MarkovLM(256, seed=3)
    a = ShardedLoader(src, 4, 16, 0, 2, seed=5)
    b = ShardedLoader(src, 4, 16, 1, 2, seed=5)
    xa, _ = a.next()
    xb, _ = b.next()
    assert not np.array_equal(xa, xb)


def test_markov_is_learnable_signal():
    """An order-2 Markov stream has lower conditional entropy than uniform."""
    src = MarkovLM(256, seed=0)
    rng = np.random.default_rng(0)
    x, y = src.sample(rng, 64, 128)
    assert x.max() < src.v            # latent alphabet
    # empirical bigram predictability beats uniform
    from collections import Counter, defaultdict
    ctx = defaultdict(Counter)
    for row_x, row_y in zip(x, y):
        for t in range(1, len(row_x)):
            ctx[(row_x[t - 1], row_x[t])][row_y[t]] += 1
    correct = total = 0
    for c, cnt in ctx.items():
        correct += cnt.most_common(1)[0][1]
        total += sum(cnt.values())
    assert correct / total > 2.0 / src.v


# seeded stand-in for the original hypothesis property test: 30 random draws
@pytest.mark.parametrize("seed", [int(s) for s in
                                  np.random.default_rng(42).integers(0, 1000,
                                                                     30)])
def test_topk_error_feedback_conserves_mass(seed):
    rng = np.random.default_rng(seed)
    comp = ErrorFeedbackCompressor(0.25)
    total_sent = np.zeros(64, np.float32)
    total_true = np.zeros(64, np.float32)
    for _ in range(8):
        g = rng.normal(size=64).astype(np.float32)
        total_true += g
        idx, vals = comp.compress("w", g)
        total_sent += topk_decompress(idx, vals, 64)
    resid = comp._residual["w"]
    np.testing.assert_allclose(total_sent + resid, total_true, atol=1e-4)


def test_optimizers_basic():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.full((4,), 2.0)}
    for name, expect in (("sgd", -0.2), ("momentum", -0.2)):
        opt = make_optimizer(name, 0.1)
        st_ = opt.init(params)
        d, st_ = opt.update(grads, st_, params)
        np.testing.assert_allclose(np.asarray(d["w"]), expect, rtol=1e-6)
    opt = make_optimizer("adamw", 0.1, weight_decay=0.0)
    st_ = opt.init(params)
    d, st_ = opt.update(grads, st_, params)
    np.testing.assert_allclose(np.asarray(d["w"]), -0.1, rtol=1e-4)


def test_allocation_policies_paper_table3():
    """NP/ED/HD reproduce the shape of the paper's Table 3 and the straggler
    ranking: ED/HD balance VW throughput; NP is straggler-bound."""
    nodes = [Node(PAPER_GPUS[c], 4) for c in "VRGQ"]
    cfg = ARCHS["h2o-danube-1.8b"]
    rep, ths = {}, {}
    for pol in ("NP", "ED", "HD"):
        vws = allocate(nodes, pol)
        assert len(vws) == 4 and all(len(v) == 4 for v in vws)
        th = vw_throughputs(cfg, vws, 4096, 4 * 4096, nm=4)
        rep[pol], ths[pol] = straggler_report(th), th
    # NP: whimpy-GPU VWs cannot even fit the model (the paper's "ResNet-152
    # too big to be loaded in four whimpy GPUs" phenomenon)
    assert (ths["NP"] == 0).sum() >= 1
    # ED: identical VWs, perfectly balanced; HD: all feasible, near-balanced
    assert rep["ED"]["imbalance"] < 1.01
    assert (ths["HD"] > 0).all() and rep["HD"]["imbalance"] < 1.15
    # WSP rate (sum) dominates BSP rate (N x min) under heterogeneity
    assert rep["NP"]["wsp_rate"] > rep["NP"]["bsp_rate"]
    assert rep["HD"]["wsp_rate"] >= rep["HD"]["bsp_rate"]


def test_allocation_ed_same_multiset():
    nodes = [Node(PAPER_GPUS[c], 4) for c in "VRGQ"]
    vws = allocate(nodes, "ED")
    names = [tuple(sorted(g.name for g in vw)) for vw in vws]
    assert len(set(names)) == 1                  # identical VW composition
