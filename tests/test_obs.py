"""The observability layer (repro.obs): tracer/metrics semantics, the
zero-overhead disabled path, Chrome-trace export schema, the WSP staleness
audit, scheduler event invariants and Telemetry report plumbing."""
import json
import time

import numpy as np
import pytest

from repro.api import (BSP, ClusterSpec, Engine, Plan, RunSpec, ServeReport,
                       ServeSpec, Telemetry, WSP, get_preset)
from repro.api.serving import Request, Scheduler
from repro.configs import ARCHS, reduced
from repro.core.wave import tick_schedule
from repro.obs import (NULL_SPAN, NULL_TRACER, Histogram, MetricsRegistry,
                       Tracer, emit_pipeline_ticks)
from repro.obs.export import load, to_chrome, validate_chrome, write_chrome
from repro.obs.metrics import INT_BOUNDS, quantile_from_snapshot
from repro.obs.summary import main as summary_main, summarize


def _cfg(**over):
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=256,
                num_microbatches=2)
    base.update(over)
    return reduced(ARCHS["qwen3-0.6b"], **base)


def _wsp_plan(**over):
    kw = dict(arch=_cfg(),
              cluster=ClusterSpec(num_vw=2, topology="2node"),
              sync=WSP(D=1),
              run=RunSpec(max_waves=3, batch=4, seq=16))
    kw.update(over)
    return Plan(**kw)


# ---------------------------------------------------------------------------
# metrics: histogram + registry semantics
# ---------------------------------------------------------------------------
def test_histogram_buckets_and_exact_sidecars():
    h = Histogram(bounds=(1, 2, 4))
    for v in (0, 1, 1.5, 3, 100):
        h.observe(v)
    assert h.counts == [2, 1, 1, 1]          # last = overflow
    assert h.count == 5 and h.vmin == 0 and h.vmax == 100
    assert h.total == pytest.approx(105.5)
    # quantiles resolve to bucket upper edges; overflow to the exact max
    assert h.quantile(0.1) == 1
    assert h.quantile(0.5) == 2           # 3rd of 5 samples sits in (1, 2]
    assert h.quantile(0.99) == 100
    snap = h.snapshot()
    assert quantile_from_snapshot(snap, 0.5) == h.quantile(0.5)
    assert quantile_from_snapshot(snap, 0.99) == 100
    assert quantile_from_snapshot({}, 0.5) is None
    assert quantile_from_snapshot(None, 0.5) is None


def test_registry_roundtrip_and_disabled_noop():
    m = MetricsRegistry()
    m.counter_inc("a")
    m.counter_inc("a", 2.0)
    m.gauge_set("g", 7)
    m.observe("h", 3, bounds=INT_BOUNDS)
    snap = m.snapshot()
    assert snap["counters"]["a"] == 3.0
    assert snap["gauges"]["g"] == 7.0
    assert snap["histograms"]["h"]["count"] == 1
    off = MetricsRegistry(enabled=False)
    off.counter_inc("a")
    off.gauge_set("g", 1)
    off.observe("h", 1)
    assert off.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


# ---------------------------------------------------------------------------
# tracer: disabled is a true no-op; enabled records typed events
# ---------------------------------------------------------------------------
def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    # span() hands back the shared singleton: no per-call allocation
    assert tr.span("t", "x") is NULL_SPAN
    assert NULL_TRACER.span("t", "y", a=1) is NULL_SPAN
    with tr.span("t", "x"):
        pass
    tr.add_span("t", "x", 0.0, 1.0)
    tr.instant("t", "x")
    tr.counter("t", "c", 3)
    tr.metrics.observe("h", 1)
    assert len(tr) == 0
    assert tr.metrics.snapshot() == {"counters": {}, "gauges": {},
                                     "histograms": {}}


def test_disabled_tracer_hot_path_overhead():
    """The disabled hot path must cost no measurable per-wave time: 100k
    span() calls in well under a second (they are a flag check + singleton
    return). A generous absolute bound keeps this robust on slow CI."""
    tr = Tracer(enabled=False)
    t0 = time.monotonic()
    for _ in range(100_000):
        with tr.span("track", "name", k=1):
            pass
        tr.counter("track", "c", 1)
    assert time.monotonic() - t0 < 1.0
    assert len(tr) == 0


def test_tracer_records_span_instant_counter():
    t = {"v": 0.0}

    def clk():
        t["v"] += 1.0
        return t["v"]

    tr = Tracer(clock=clk)
    with tr.span("trk", "work", tag="x"):
        pass
    tr.instant("trk", "mark", n=1)
    tr.counter("trk", "depth", 4)
    evs = tr.events()
    assert [e[0] for e in evs] == ["X", "i", "C"]
    ph, track, name, t0, dur, args = evs[0]
    assert (track, name, t0, dur, args) == ("trk", "work", 1.0, 1.0,
                                            {"tag": "x"})
    assert evs[2][5] == {"depth": 4}


# ---------------------------------------------------------------------------
# pipeline tick rendering
# ---------------------------------------------------------------------------
def test_tick_schedule_shapes():
    sched, ticks = tick_schedule(2, 2)
    assert ticks == 3                     # nm + (stages-1), skew 1
    assert len(sched) == 2 * 3
    for s in range(2):
        mbs = [mb for st, _, mb in sched if st == s and mb >= 0]
        assert mbs == [0, 1]              # every stage runs every microbatch
    _, ticks_ov = tick_schedule(3, 4, overlap=True)
    assert ticks_ov == 4 + 2 * 2          # skew 2 under overlap


def test_emit_pipeline_ticks_spans_and_bubble_fraction():
    tr = Tracer(clock=lambda: 0.0)
    sched, ticks = tick_schedule(2, 2)
    emit_pipeline_ticks(tr, "vw0", sched, ticks, 0.0, 3.0)
    evs = tr.events()
    assert len(evs) == 2 * 3              # one span per (stage, tick)
    assert {e[1] for e in evs} == {"vw0/stage0", "vw0/stage1"}
    bubbles = [e for e in evs if e[2] == "bubble"]
    assert len(bubbles) == 2              # 1 bubble tick per stage
    snap = tr.metrics.snapshot()["counters"]
    assert snap["pipe/busy_s"] == pytest.approx(4.0)
    assert snap["pipe/bubble_s"] == pytest.approx(2.0)
    # disabled: no events, no counters
    emit_pipeline_ticks(NULL_TRACER, "vw0", sched, ticks, 0.0, 3.0)
    assert len(NULL_TRACER) == 0


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------
def _sample_tracer():
    t = {"v": 10.0}

    def clk():
        t["v"] += 0.5
        return t["v"]

    tr = Tracer(clock=clk)
    with tr.span("alpha", "work"):
        tr.instant("beta", "mark")
    tr.counter("alpha", "depth", 2)
    return tr


def test_export_chrome_schema(tmp_path):
    tr = _sample_tracer()
    doc = to_chrome(tr.events(), telemetry=tr.metrics.snapshot())
    validate_chrome(doc)
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["name"] == "process_name"
    # every track got thread_name metadata; tids are stable per track
    names = {e["args"]["name"]: e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert set(names) == {"alpha", "beta"}
    xs = [e for e in evs if e["ph"] == "X"]
    ins = [e for e in evs if e["ph"] == "i"]
    assert len(xs) == 1 and len(ins) == 1
    assert xs[0]["tid"] == names["alpha"] and xs[0]["dur"] > 0
    assert ins[0]["s"] == "t"
    # timestamps are µs relative to the earliest event
    assert min(e["ts"] for e in evs if e["ph"] != "M") == 0
    p = tmp_path / "t.json"
    assert write_chrome(tr.events(), str(p)) == str(p)
    assert load(str(p))["traceEvents"]
    json.loads(p.read_text())             # plain JSON on disk


def test_validate_chrome_rejects_malformed():
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome({})
    with pytest.raises(ValueError, match="empty"):
        validate_chrome({"traceEvents": []})
    tr = _sample_tracer()
    doc = to_chrome(tr.events())
    bad = json.loads(json.dumps(doc))
    bad["traceEvents"][1]["ph"] = "Z"
    with pytest.raises(ValueError, match="ph"):
        validate_chrome(bad)
    bad2 = json.loads(json.dumps(doc))
    for e in bad2["traceEvents"]:
        if e["ph"] == "X":
            e["ts"] = -5
    with pytest.raises(ValueError, match="ts"):
        validate_chrome(bad2)


def test_summary_cli_exit_codes(tmp_path):
    tr = _sample_tracer()
    p = tmp_path / "ok.json"
    tr.export(str(p))
    assert summary_main([str(p)]) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{}")
    assert summary_main([str(bad)]) == 1
    assert summary_main([str(tmp_path / "missing.json")]) == 1


# ---------------------------------------------------------------------------
# instrumented training: bit-identity, staleness audit, wait_seconds
# ---------------------------------------------------------------------------
def test_fit_bit_identical_with_and_without_tracer():
    """Tracing must observe, never perturb: loss sequences are bit-identical
    between an untraced and a traced engine. Deterministic configs only —
    a single-VW WSP fleet and the sequential BSP loop; multi-VW WSP loss
    streams depend on thread interleaving with or without tracing."""
    def solo():
        return _wsp_plan(cluster=ClusterSpec(num_vw=1), sync=WSP(D=0))
    plain = Engine(solo()).fit()
    tr = Tracer()
    traced = Engine(solo(), tracer=tr).fit()
    a, b = plain.losses_by_worker(), traced.losses_by_worker()
    assert a.keys() == b.keys()
    for wid in a:
        assert a[wid] == b[wid]           # exact float equality
    assert plain.telemetry is None
    assert traced.telemetry is not None
    assert len(tr) > 0

    def bsp():
        return Plan(arch=_cfg(), cluster=ClusterSpec(num_vw=2,
                                                     topology="2node"),
                    sync=BSP(), run=RunSpec(max_waves=2, batch=4, seq=16))
    p = Engine(bsp()).fit()
    t = Engine(bsp(), tracer=Tracer()).fit()
    assert p.losses_by_worker() == t.losses_by_worker()


def test_generate_bit_identical_with_and_without_tracer():
    def plan():
        return Plan(arch=_cfg(), run=RunSpec(),
                    serve=ServeSpec(prompt_len=8, gen=4, max_batch=2))
    plain = Engine(plan()).generate()
    traced = Engine(plan(), tracer=Tracer()).generate()
    np.testing.assert_array_equal(np.asarray(plain.tokens),
                                  np.asarray(traced.tokens))
    assert plain.telemetry is None
    assert traced.telemetry is not None
    assert traced.prefill_calls == 1


def test_traced_wsp_staleness_audited_against_D(tmp_path):
    tr = Tracer()
    plan = _wsp_plan(cluster=ClusterSpec(num_vw=2, topology="2node",
                                         speeds=(0.0, 0.05)),
                     sync=WSP(D=2, pull_every=2, async_push=True),
                     run=RunSpec(max_waves=4, batch=4, seq=16))
    rep = Engine(plan, tracer=tr).fit()
    tel = rep.telemetry
    st = tel.histograms["wsp/staleness"]
    assert st["count"] >= plan.run.max_waves     # one sample per wave per VW
    assert st["max"] <= 2                        # the gate's guarantee
    assert tel.gauges["wsp/D"] == 2
    assert "wsp/staleness_violations" not in tel.counters
    assert tel.bubble_fraction() == pytest.approx(1 / 3)   # 2 stages, 2 mb
    assert any(k.startswith("link/") for k in tel.gauges)
    # the summary CLI performs the same audit on the exported trace
    p = tmp_path / "wsp.json"
    tr.export(str(p))
    lines = summarize(load(str(p)))
    assert any("bound D=2 -> OK" in ln for ln in lines)
    # a doctored trace whose measured max exceeds D must fail the audit
    doc = load(str(p))
    doc["telemetry"]["histograms"]["wsp/staleness"]["max"] = 3
    with pytest.raises(ValueError, match="staleness audit failed"):
        summarize(doc)


def test_wait_seconds_normalized_across_backends():
    # threads: wid -> gate-blocked seconds for every worker
    rep = Engine(_wsp_plan()).fit()
    assert sorted(rep.wait_seconds) == ["vw0", "vw1"]
    # bsp: wid -> straggler wait; the slowed worker waits least
    bsp = Plan(arch=_cfg(),
               cluster=ClusterSpec(num_vw=2, topology="2node",
                                   speeds=(0.0, 0.05)),
               sync=BSP(), run=RunSpec(max_waves=2, batch=4, seq=16))
    rep = Engine(bsp).fit()
    assert sorted(rep.wait_seconds) == ["vw0", "vw1"]
    assert all(v >= 0 for v in rep.wait_seconds.values())
    # the barrier charges somebody: with asymmetric speeds the faster
    # worker waits (direction is not asserted — first-call jit compile
    # can land on either worker's measured wave time)
    assert max(rep.wait_seconds.values()) > 0
    # spmd: the jitted step has no host-visible gate, but the key exists
    rep = Engine(get_preset("spmd_tiny").replace(run__max_waves=1)).fit()
    assert rep.wait_seconds == {"spmd": 0.0}


# ---------------------------------------------------------------------------
# scheduler: event invariants, admission groups, TTFT
# ---------------------------------------------------------------------------
def _sched_run(n_requests=6, tracer=None):
    plan = Plan(arch=_cfg(),
                serve=ServeSpec(prompt_len=8, gen=3, max_batch=2,
                                page_size=4),
                run=RunSpec())
    eng = Engine(plan, tracer=tracer) if tracer else Engine(plan)
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 256, size=int(rng.integers(2, 9)),
                                        dtype=np.int32).astype(np.int32))
            for i in range(n_requests)]
    return Scheduler(eng).run(reqs), eng


def test_scheduler_event_invariants():
    tr = Tracer()
    rep, _ = _sched_run(tracer=tr)
    evs = tr.events()
    admits = [e for e in evs if e[0] == "i" and e[2] == "admit"]
    retires = [e for e in evs if e[0] == "i" and e[2] == "retire"]
    # every admitted request retires (run drains the queue); rids match 1:1
    assert sorted(e[5]["rid"] for e in admits) == list(range(6))
    assert sorted(e[5]["rid"] for e in retires) == list(range(6))
    # decode-step slot counts reconcile with the report
    steps = [e for e in evs if e[0] == "X" and e[2] == "decode_step"]
    assert len(steps) == rep.decode_steps
    assert sum(e[5]["slots"] for e in steps) == rep.slot_steps
    # prefill groups: one span per batched prefill call
    groups = [e for e in evs if e[0] == "X" and e[2] == "prefill_group"]
    assert len(groups) == rep.prefill_calls
    assert {e[5]["group"] for e in groups} == \
        {e[5]["group"] for e in admits}


def test_scheduler_groups_and_ttft():
    rep, _ = _sched_run()
    assert rep.prefill_calls >= 2          # 6 requests through 2 slots
    by_group: dict = {}
    for r in rep.requests:
        assert 0 <= r.group < rep.prefill_calls
        assert r.ttft_s > 0
        by_group.setdefault(r.group, []).append(r)
    # an admission group shares one prefill cost and one TTFT
    for rs in by_group.values():
        assert len({r.prefill_s for r in rs}) == 1
        assert len({r.ttft_s for r in rs}) == 1
    # group-attributed cost: mean_ttft uses each group's cost once, so it
    # never exceeds the run's wall clock (summing per-request prefill_s
    # over co-batched requests would)
    assert rep.mean_ttft() <= rep.wall_s
    assert sum({r.group: r.prefill_s for r in rep.requests}.values()) == \
        pytest.approx(rep.prefill_s)
    # later groups admit later, so TTFT grows with the group id
    ttfts = {r.group: r.ttft_s for r in rep.requests}
    ordered = [ttfts[g] for g in sorted(ttfts)]
    assert ordered == sorted(ordered)
    assert ServeReport().mean_ttft() is None


def test_telemetry_helpers():
    m = MetricsRegistry()
    m.observe("wsp/staleness", 1, bounds=INT_BOUNDS)
    m.observe("wsp/staleness", 2, bounds=INT_BOUNDS)
    m.counter_inc("pipe/busy_s", 3.0)
    m.counter_inc("pipe/bubble_s", 1.0)
    m.gauge_set("link/eth/bytes", 1e6)
    m.gauge_set("link/eth/modeled_s", 0.5)
    tel = Telemetry.from_metrics(m)
    assert tel.staleness_max() == 2
    assert tel.hist_quantile("wsp/staleness", 0.5) == 1
    assert tel.bubble_fraction() == pytest.approx(0.25)
    assert tel.link_utilization(1.0) == {"eth": 0.5}
    assert tel.to_dict()["gauges"]["link/eth/bytes"] == 1e6
    assert Telemetry().staleness_max() is None
    assert Telemetry().bubble_fraction() is None
