"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.kernels.mamba_ssd import ssd_chunked
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.rwkv6_scan import rwkv6_chunked

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 64, 16), (2, 4, 2, 128, 32), (1, 8, 1, 96, 64),
])
@pytest.mark.parametrize("window", [0, 40])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, KV, S, hd, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    ref = kref.attention_ref(q, k, v, causal=True, window=window)
    out = flash_attention_fwd(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (2, 4, 2, 128, 32),           # GQA G=2
    (1, 6, 6, 64, 16),            # MHA: G == 1, H == KV
])
@pytest.mark.parametrize("length", [0, 1, 37, 64])
@pytest.mark.parametrize("window", [0, 48, 96])
def test_flash_decode(B, H, KV, S, hd, length, window):
    """Sweep covers the contract edges: length == 0 (zeros, not a uniform
    average over uninitialized V) and window >= length (full coverage,
    window mask inert)."""
    ks = jax.random.split(KEY, 3)
    q1 = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    ref = kref.decode_ref(q1, k, v, length, window=window)
    out = flash_decode(q1, k, v, length, window=window, block_k=32,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
    if length == 0:
        assert np.all(np.asarray(out) == 0.0)
        assert np.all(np.asarray(ref) == 0.0)


def test_flash_decode_zero_length_ignores_uninitialized_v():
    """length == 0 emits exact zeros even when the unwritten cache holds
    garbage — the old oracle softmax averaged V uniformly instead."""
    B, H, KV, S, hd = 2, 4, 2, 32, 16
    q1 = jax.random.normal(KEY, (B, H, hd))
    k = jnp.full((B, KV, S, hd), 1e6)
    v = jnp.full((B, KV, S, hd), -1e6)
    assert np.all(np.asarray(kref.decode_ref(q1, k, v, 0)) == 0.0)
    assert np.all(np.asarray(
        flash_decode(q1, k, v, 0, block_k=16, interpret=True)) == 0.0)


def test_flash_decode_per_row_lengths():
    """A [B] int32 length vector masks each row at its own depth — the
    serve decode path's mixed-depth batches."""
    B, H, KV, S, hd = 4, 4, 2, 64, 16
    ks = jax.random.split(KEY, 3)
    q1 = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    lens = jnp.asarray([0, 1, 33, 64], jnp.int32)
    ref = kref.decode_ref(q1, k, v, lens)
    out = flash_decode(q1, k, v, lens, block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)
    assert np.all(np.asarray(out[0]) == 0.0)            # length-0 row
    # each row matches a scalar-length call at its own depth
    for b, n in enumerate([0, 1, 33, 64]):
        one = flash_decode(q1[b:b + 1], k[b:b + 1], v[b:b + 1], n,
                           block_k=16, interpret=True)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(one[0]),
                                   atol=2e-5, rtol=2e-5)


def test_flash_decode_unaligned_cache_pads_not_degrades(caplog):
    """A prime cache length no longer silently degrades block_k to 1 —
    the KV view is padded to a block multiple (dead, masked) and the
    fallback is logged."""
    import logging
    B, H, KV, S, hd = 2, 4, 2, 37, 16
    ks = jax.random.split(KEY, 3)
    q1 = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    ref = kref.decode_ref(q1, k, v, 37)
    with caplog.at_level(logging.WARNING, logger="repro.kernels"):
        out = flash_decode(q1, k, v, 37, block_k=16, interpret=True)
    assert any("padding" in r.message for r in caplog.records)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("pool_dtype", [jnp.float32, jnp.float8_e4m3fn])
def test_flash_decode_paged(pool_dtype):
    """The paged kernel walks the stacked pool [groups, pages+1, ps, KV,
    hd] through the block table inside the index map: unmapped (-1)
    entries route to the trash page, rows mask at their own length, and
    a length-0 row emits zeros."""
    L, P1, ps, B, KV, G, hd = 2, 9, 4, 3, 2, 2, 16
    H = KV * G
    ks = jax.random.split(KEY, 4)
    pool_k = jax.random.normal(ks[0], (L, P1, ps, KV, hd)).astype(pool_dtype)
    pool_v = jax.random.normal(ks[1], (L, P1, ps, KV, hd)).astype(pool_dtype)
    q1 = jax.random.normal(ks[2], (B, H, hd))
    tab = jnp.asarray([[0, 3, 6], [1, 4, -1], [-1, -1, -1]], jnp.int32)
    lens = jnp.asarray([11, 6, 0], jnp.int32)
    for layer in (0, 1):
        ref = kref.decode_paged_ref(q1, pool_k, pool_v, tab, lens,
                                    layer=layer)
        out = flash_decode_paged(q1, pool_k, pool_v, tab, lens,
                                 layer=layer, interpret=True)
        tol = 1e-1 if pool_dtype == jnp.float8_e4m3fn else 2e-5
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=tol, rtol=tol)
        assert np.all(np.asarray(out[2]) == 0.0)        # empty slot


def test_flash_decode_paged_matches_contiguous():
    """Scattering a contiguous cache across out-of-order pages and reading
    it back through the block table reproduces the contiguous kernel."""
    B, KV, G, hd, ps, npg = 2, 2, 2, 16, 4, 4
    H, S = KV * G, ps * 4
    ks = jax.random.split(KEY, 3)
    q1 = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    P1 = B * npg + 1
    perm = np.random.default_rng(3).permutation(B * npg)
    tab = jnp.asarray(perm.reshape(B, npg), jnp.int32)
    pool_k = jnp.zeros((1, P1, ps, KV, hd))
    pool_v = jnp.zeros((1, P1, ps, KV, hd))
    for b in range(B):
        for pi in range(npg):
            blk_k = k[b, :, pi * ps:(pi + 1) * ps].transpose(1, 0, 2)
            blk_v = v[b, :, pi * ps:(pi + 1) * ps].transpose(1, 0, 2)
            pool_k = pool_k.at[0, perm[b * npg + pi]].set(blk_k)
            pool_v = pool_v.at[0, perm[b * npg + pi]].set(blk_v)
    lens = jnp.asarray([S, S - 3], jnp.int32)
    ref = flash_decode(q1, k, v, lens, block_k=ps, interpret=True)
    out = flash_decode_paged(q1, pool_k, pool_v, tab, lens, layer=0,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_backend_registry():
    """set_backend validates eagerly (ValueError, not a strippable
    assert); use_backend scopes and restores the process global."""
    assert kops.check_backend("ref") == "ref"
    with pytest.raises(ValueError, match="unknown kernel backend"):
        kops.set_backend("cuda")
    before = kops.KERNEL_BACKEND
    with kops.use_backend("interpret"):
        assert kops.KERNEL_BACKEND == "interpret"
        with kops.use_backend("ref"):
            assert kops.KERNEL_BACKEND == "ref"
        assert kops.KERNEL_BACKEND == "interpret"
    assert kops.KERNEL_BACKEND == before
    with pytest.raises(ValueError):
        with kops.use_backend("mosaic"):
            pass
    assert kops.KERNEL_BACKEND == before


def test_ops_dispatch_uses_ambient_backend():
    """ops.decode_attention honors use_backend when no explicit backend
    is passed, and both routes agree."""
    B, H, KV, S, hd = 2, 4, 2, 32, 16
    ks = jax.random.split(KEY, 3)
    q1 = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    lens = jnp.asarray([32, 7], jnp.int32)
    ref = kops.decode_attention(q1, k, v, lens)       # default "ref"
    with kops.use_backend("interpret"):
        out = kops.decode_attention(q1, k, v, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("B,H,S,hd", [(1, 2, 64, 16), (2, 3, 96, 32)])
@pytest.mark.parametrize("chunk", [8, 16])
def test_rwkv6_chunked(B, H, S, hd, chunk):
    ks = jax.random.split(KEY, 5)
    r, k, v = (0.5 * jax.random.normal(ks[i], (B, H, S, hd))
               for i in range(3))
    w = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (B, H, S, hd)),
                          -8.0, 1.386))
    u = 0.3 * jnp.ones((H, hd)) + 0.1 * jax.random.normal(ks[4], (H, hd))
    y_ref, st_ref = kref.rwkv6_ref(r, k, v, w, u)
    y, st = rwkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4,
                               rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=5e-4,
                               rtol=5e-4)


@pytest.mark.parametrize("B,H,S,N,P", [(1, 2, 64, 8, 16), (2, 4, 128, 16, 32)])
@pytest.mark.parametrize("chunk", [16, 64])
def test_ssd_chunked(B, H, S, N, P, chunk):
    ks = jax.random.split(KEY, 4)
    x = 0.5 * jax.random.normal(ks[0], (B, H, S, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S)))
    Bm = 0.5 * jax.random.normal(ks[2], (B, S, N))
    Cm = 0.5 * jax.random.normal(ks[3], (B, S, N))
    a = -jnp.exp(jnp.linspace(0.0, 2.0, H))
    y_ref, h_ref = kref.ssd_ref(x, dt, Bm, Cm, a)
    y, h = ssd_chunked(x, dt, Bm, Cm, a, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("E,C,d,f", [(2, 32, 16, 24), (4, 64, 48, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul(E, C, d, f, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, d), dtype)
    w = jax.random.normal(ks[1], (E, d, f), dtype)
    ref = kref.gmm_ref(x, w)
    out = grouped_matmul(x, w, block_c=16, block_f=16, block_d=16,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_model_chunked_paths_match_kernels():
    """The model's jnp chunked rwkv6/ssd (used in the dry-run) agree with the
    sequential oracles — same math as the Pallas kernels."""
    from repro.models import ssm as mssm
    B, H, S, hd = 2, 2, 64, 16
    d = H * hd
    ks = jax.random.split(KEY, 2)
    # rwkv6 chunked-vs-step consistency via the model API
    p = {
        "mu_r": jnp.full((d,), 0.5), "mu_k": jnp.full((d,), 0.5),
        "mu_v": jnp.full((d,), 0.5), "mu_g": jnp.full((d,), 0.5),
        "mu_w": jnp.full((d,), 0.5),
        "wr": 0.1 * jax.random.normal(ks[0], (d, d)),
        "wk": 0.1 * jax.random.normal(ks[1], (d, d)),
        "wv": 0.1 * jax.random.normal(ks[0], (d, d)),
        "wg": 0.1 * jax.random.normal(ks[1], (d, d)),
        "wo": 0.1 * jax.random.normal(ks[0], (d, d)),
        "w0": jnp.full((d,), -2.0),
        "wa": jnp.zeros((d, 64)), "wb": jnp.zeros((64, d)),
        "u": jnp.full((H, hd), 0.3),
        "gn_scale": jnp.ones((d,)), "gn_bias": jnp.zeros((d,)),
    }
    x = 0.5 * jax.random.normal(ks[1], (B, S, d))
    y_chunk, stT, _ = mssm.rwkv6_mix(p, x, heads=H, chunk=16)
    # sequential: one token at a time
    st = jnp.zeros((B, H, hd, hd))
    prev = None
    outs = []
    for t in range(S):
        y_t, st, prev = mssm.rwkv6_mix_step(p, x[:, t:t + 1], st, prev,
                                            heads=H)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(stT), np.asarray(st), atol=2e-4,
                               rtol=2e-4)
