"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.flash_decode import flash_decode
from repro.kernels.mamba_ssd import ssd_chunked
from repro.kernels.moe_gmm import grouped_matmul
from repro.kernels.rwkv6_scan import rwkv6_chunked

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 64, 16), (2, 4, 2, 128, 32), (1, 8, 1, 96, 64),
])
@pytest.mark.parametrize("window", [0, 40])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, H, KV, S, hd, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    ref = kref.attention_ref(q, k, v, causal=True, window=window)
    out = flash_attention_fwd(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,H,KV,S,hd", [(2, 4, 2, 128, 32), (1, 6, 6, 64, 16)])
@pytest.mark.parametrize("length", [1, 37, 64])
@pytest.mark.parametrize("window", [0, 48])
def test_flash_decode(B, H, KV, S, hd, length, window):
    ks = jax.random.split(KEY, 3)
    q1 = jax.random.normal(ks[0], (B, H, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    ref = kref.decode_ref(q1, k, v, length, window=window)
    out = flash_decode(q1, k, v, length, window=window, block_k=32,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("B,H,S,hd", [(1, 2, 64, 16), (2, 3, 96, 32)])
@pytest.mark.parametrize("chunk", [8, 16])
def test_rwkv6_chunked(B, H, S, hd, chunk):
    ks = jax.random.split(KEY, 5)
    r, k, v = (0.5 * jax.random.normal(ks[i], (B, H, S, hd))
               for i in range(3))
    w = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (B, H, S, hd)),
                          -8.0, 1.386))
    u = 0.3 * jnp.ones((H, hd)) + 0.1 * jax.random.normal(ks[4], (H, hd))
    y_ref, st_ref = kref.rwkv6_ref(r, k, v, w, u)
    y, st = rwkv6_chunked(r, k, v, w, u, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4,
                               rtol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref), atol=5e-4,
                               rtol=5e-4)


@pytest.mark.parametrize("B,H,S,N,P", [(1, 2, 64, 8, 16), (2, 4, 128, 16, 32)])
@pytest.mark.parametrize("chunk", [16, 64])
def test_ssd_chunked(B, H, S, N, P, chunk):
    ks = jax.random.split(KEY, 4)
    x = 0.5 * jax.random.normal(ks[0], (B, H, S, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S)))
    Bm = 0.5 * jax.random.normal(ks[2], (B, S, N))
    Cm = 0.5 * jax.random.normal(ks[3], (B, S, N))
    a = -jnp.exp(jnp.linspace(0.0, 2.0, H))
    y_ref, h_ref = kref.ssd_ref(x, dt, Bm, Cm, a)
    y, h = ssd_chunked(x, dt, Bm, Cm, a, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4,
                               rtol=1e-4)


@pytest.mark.parametrize("E,C,d,f", [(2, 32, 16, 24), (4, 64, 48, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul(E, C, d, f, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (E, C, d), dtype)
    w = jax.random.normal(ks[1], (E, d, f), dtype)
    ref = kref.gmm_ref(x, w)
    out = grouped_matmul(x, w, block_c=16, block_f=16, block_d=16,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_model_chunked_paths_match_kernels():
    """The model's jnp chunked rwkv6/ssd (used in the dry-run) agree with the
    sequential oracles — same math as the Pallas kernels."""
    from repro.models import ssm as mssm
    B, H, S, hd = 2, 2, 64, 16
    d = H * hd
    ks = jax.random.split(KEY, 2)
    # rwkv6 chunked-vs-step consistency via the model API
    p = {
        "mu_r": jnp.full((d,), 0.5), "mu_k": jnp.full((d,), 0.5),
        "mu_v": jnp.full((d,), 0.5), "mu_g": jnp.full((d,), 0.5),
        "mu_w": jnp.full((d,), 0.5),
        "wr": 0.1 * jax.random.normal(ks[0], (d, d)),
        "wk": 0.1 * jax.random.normal(ks[1], (d, d)),
        "wv": 0.1 * jax.random.normal(ks[0], (d, d)),
        "wg": 0.1 * jax.random.normal(ks[1], (d, d)),
        "wo": 0.1 * jax.random.normal(ks[0], (d, d)),
        "w0": jnp.full((d,), -2.0),
        "wa": jnp.zeros((d, 64)), "wb": jnp.zeros((64, d)),
        "u": jnp.full((H, hd), 0.3),
        "gn_scale": jnp.ones((d,)), "gn_bias": jnp.zeros((d,)),
    }
    x = 0.5 * jax.random.normal(ks[1], (B, S, d))
    y_chunk, stT, _ = mssm.rwkv6_mix(p, x, heads=H, chunk=16)
    # sequential: one token at a time
    st = jnp.zeros((B, H, hd, hd))
    prev = None
    outs = []
    for t in range(S):
        y_t, st, prev = mssm.rwkv6_mix_step(p, x[:, t:t + 1], st, prev,
                                            heads=H)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(stT), np.asarray(st), atol=2e-4,
                               rtol=2e-4)
