"""repro.dist: codecs, topology cost model, collective emulation, and the
simulated transport wired through the parameter server / trainer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.allocation import Node, straggler_report_comm
from repro.core.param_server import ParameterServer
from repro.core.partition import PAPER_GPUS
from repro.core.wave import build_local_wave_step
from repro.dist import collectives
from repro.dist.compression import (ErrorFeedbackCompressor,
                                    Int8StochasticQuantizer, make_codec,
                                    topk_compress, topk_decompress)
from repro.dist.topology import (ClusterTopology, LinkSpec, Pod, ETH_10G,
                                 IB_100G, NVLINK, PCIE, make_topology)
from repro.dist.transport import NullTransport, SimulatedTransport
from repro.models import lm
from repro.optim import make_optimizer
from repro.runtime.trainer import WSPTrainer, bsp_allreduce_baseline


def _trees(n, seed=0, shapes=((3, 4), (7,), ())):
    rng = np.random.default_rng(seed)
    return [{f"p{j}": rng.normal(size=s).astype(np.float32)
             for j, s in enumerate(shapes)} for _ in range(n)]


def _np_sum(trees):
    return jax.tree.map(
        lambda *xs: np.sum(np.stack([np.asarray(x) for x in xs]), 0), *trees)


# -- collectives ----------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 3, 5])
def test_ring_allreduce_matches_numpy(n):
    trees = _trees(n)
    out, cost = collectives.ring_allreduce(trees)
    ref = _np_sum(trees)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(a, b, atol=1e-5)
    assert cost == 0.0                       # untimed without a topology


def test_ring_allreduce_average():
    trees = _trees(4, seed=1)
    out, _ = collectives.ring_allreduce(trees, average=True)
    ref = jax.tree.map(lambda x: x / 4.0, _np_sum(trees))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_reduce_scatter_all_gather_roundtrip():
    vecs = [np.random.default_rng(s).normal(size=64).astype(np.float32)
            for s in range(4)]
    chunks = collectives.ring_reduce_scatter(vecs)
    full = collectives.ring_all_gather(chunks)
    np.testing.assert_allclose(full, np.sum(vecs, 0), atol=1e-5)


def test_hierarchical_matches_ring_and_is_cheaper_cross_pod():
    topo = make_topology("2node", 8)
    trees = _trees(8, seed=2)
    ring, c_ring = collectives.ring_allreduce(trees, topology=topo)
    hier, c_hier = collectives.hierarchical_allreduce(trees, topology=topo)
    for a, b in zip(jax.tree.leaves(ring), jax.tree.leaves(hier)):
        np.testing.assert_allclose(a, b, atol=1e-5)
    # the full vector crosses the slow tier 2(P-1)/P times instead of
    # 2(W-1)/W: hierarchical must win on a 2-pod Ethernet cluster
    assert 0 < c_hier < c_ring


# -- compression ----------------------------------------------------------

def test_topk_roundtrip_and_wire_bytes():
    g = np.arange(-8, 8, dtype=np.float32)
    idx, vals = topk_compress(g, 0.25)
    assert idx.size == 4
    dense = topk_decompress(idx, vals, g.size)
    assert set(np.flatnonzero(dense)) == set(idx.tolist())
    comp = ErrorFeedbackCompressor(0.25)
    assert comp.wire_bytes(idx, vals) == 4 * (4 + 4)


@pytest.mark.parametrize("seed", range(5))
def test_error_feedback_mass_conservation(seed):
    rng = np.random.default_rng(seed)
    comp = ErrorFeedbackCompressor(0.1)
    sent = np.zeros(128, np.float32)
    true = np.zeros(128, np.float32)
    for _ in range(12):
        g = rng.normal(size=128).astype(np.float32)
        true += g
        idx, vals = comp.compress("k", g)
        sent += topk_decompress(idx, vals, 128)
    np.testing.assert_allclose(sent + comp._residual["k"], true, atol=1e-4)


def test_int8_stochastic_rounding_unbiased():
    q8 = Int8StochasticQuantizer(seed=0)
    x = np.full(20_000, 0.3337, np.float32)
    qv, scale = q8.quantize(x)
    # E[q * scale] == x: the mean over many stochastic roundings recovers x
    assert abs(float(np.mean(q8.dequantize(qv, scale))) - 0.3337) < 1e-3
    # per-entry error bounded by one quantization step
    assert float(np.max(np.abs(q8.dequantize(qv, scale) - x))) <= scale + 1e-6
    idx, vals = q8.compress("k", x)
    assert q8.wire_bytes(idx, vals) == x.size + 4     # 1 B/entry + scale


def test_make_codec_specs():
    assert make_codec(None) is None
    assert make_codec("none") is None
    assert isinstance(make_codec("topk:0.5"), ErrorFeedbackCompressor)
    assert isinstance(make_codec(0.5), ErrorFeedbackCompressor)
    assert isinstance(make_codec("int8"), Int8StochasticQuantizer)
    with pytest.raises(ValueError):
        make_codec("gzip")


# -- topology -------------------------------------------------------------

def test_topology_cost_monotonicity():
    topo = make_topology("2node", 4)
    # more bytes => strictly higher cost
    assert topo.p2p_cost("vw0", "vw2", 2e6) > topo.p2p_cost("vw0", "vw2", 1e6)
    # intra-pod (NVLink) beats inter-pod (Ethernet)
    assert topo.p2p_cost("vw0", "vw1", 1e6) < topo.p2p_cost("vw0", "vw2", 1e6)
    # slower link class => higher cost at equal bytes
    fast = LinkSpec("fast", 100.0, 1e-6)
    slow = LinkSpec("slow", 1.0, 1e-6)
    assert slow.transfer_time(1e6) > fast.transfer_time(1e6)
    # IB inter-node beats 10G Ethernet
    eth = make_topology("2node", 4)
    ib = make_topology("2node:ib", 4)
    assert ib.p2p_cost("vw0", "vw3", 1e7) < eth.p2p_cost("vw0", "vw3", 1e7)


def test_topology_ps_placement_and_collective_costs():
    topo = make_topology("2node", 4)
    assert topo.p2p_cost("vw0", "ps", 1e6) == 0.0      # PS hosted on vw0
    assert topo.p2p_cost("vw3", "ps", 1e6) > 0.0       # cross-pod push
    ws = topo.worker_names()
    assert ws == ["vw0", "vw1", "vw2", "vw3"]
    assert topo.ring_allreduce_cost(ws, 1e7) > \
        topo.reduce_scatter_cost(ws, 1e7)
    # a one-worker "collective" is free
    assert topo.ring_allreduce_cost(["vw0"], 1e7) == 0.0


def test_topology_from_fleet_and_presets():
    nodes = [Node(PAPER_GPUS[c], 4) for c in "VRGQ"]
    topo = ClusterTopology.from_fleet(nodes, num_vw=4)
    assert sorted(topo.worker_names()) == [f"vw{i}" for i in range(4)]
    # each VW sits on its own node: every pair crosses Ethernet
    assert topo.link("vw0", "vw1") is topo.inter
    assert make_topology("paper", 4).p2p_cost("vw1", "ps", 1e6) > 0
    assert make_topology(None, 4) is None
    assert make_topology("none", 4) is None
    hetero = make_topology("hetero-2node", 4)
    assert hetero.link("vw0", "vw1").name == NVLINK.name
    assert hetero.link("vw2", "vw3").name == PCIE.name


def test_comm_aware_straggler_report():
    topo = make_topology("2node", 4)
    th = np.array([10.0, 10.0, 10.0, 10.0])
    rep = straggler_report_comm(th, topo, bytes_per_wave=50e6)
    # balanced compute, but vw2/vw3 push over Ethernet: comm makes stragglers
    assert rep["compute_only"]["imbalance"] == pytest.approx(1.0)
    assert rep["imbalance"] > 1.0
    assert rep["comm_seconds"][0] == 0.0 and rep["comm_seconds"][3] > 0.0
    assert rep["wsp_rate"] < rep["compute_only"]["wsp_rate"]


# -- transport + parameter server ----------------------------------------

def _params():
    return {"a": np.ones((8, 8), np.float32), "b": np.zeros(16, np.float32)}


def test_ps_wire_byte_accounting():
    deltas = {"a": np.ones((8, 8), np.float32),
              "b": np.ones(16, np.float32)}
    dense = 64 * 4 + 16 * 4
    ps = ParameterServer(_params(), D=0)
    ps.register("w0")
    ps.push_wave("w0", deltas)
    assert ps.bytes_pushed == dense and ps.bytes_wire == dense
    psc = ParameterServer(_params(), D=0, codec="topk:0.25")
    psc.register("w0")
    psc.push_wave("w0", deltas)
    assert psc.bytes_pushed == dense
    assert 0 < psc.bytes_wire < psc.bytes_pushed


def test_simulated_transport_accounts_and_delays():
    topo = make_topology("2node", 2)
    tr = SimulatedTransport(topo, time_scale=1.0)
    cost = tr.send("vw1", "ps", int(1e6))            # crosses Ethernet
    assert cost == pytest.approx(ETH_10G.transfer_time(1e6))
    assert tr.bytes_by_link[ETH_10G.name] == int(1e6)
    assert tr.stats()["modeled_seconds"] > 0
    assert tr.send("vw0", "ps", int(1e6)) == 0.0     # PS-local push is free
    assert NullTransport().send("a", "b", 100) == 0.0


def test_send_async_defers_delay_but_accounts_immediately():
    """send_async must price and account the message at issue time, pay the
    (scaled) delay only in wait(), and wait() must be idempotent."""
    import time
    topo = make_topology("2node", 2)
    tr = SimulatedTransport(topo, time_scale=1.0)
    t0 = time.monotonic()
    h = tr.send_async("vw1", "ps", int(1e8))         # ~80ms on 10G Ethernet
    issue_s = time.monotonic() - t0
    assert issue_s < 0.5 * h.seconds                 # issue did not sleep
    assert h.seconds == pytest.approx(ETH_10G.transfer_time(1e8))
    assert tr.bytes_by_link[ETH_10G.name] == int(1e8)   # accounted already
    assert not h.done()
    t1 = time.monotonic()
    assert h.wait() == h.seconds
    assert time.monotonic() - t1 >= 0.5 * h.seconds  # wait paid the delay
    assert h.done()
    t2 = time.monotonic()
    h.wait()                                         # idempotent: no re-sleep
    assert time.monotonic() - t2 < 0.5 * h.seconds
    # a local (free) transfer completes at issue time
    assert tr.send_async("vw0", "ps", 100).done()


def test_ps_pull_caches_unchanged_shards():
    """pull() must serve leaf snapshots from cache while the owning shard's
    version is unchanged, and re-copy after a push touches it."""
    ps = ParameterServer(_params(), D=0, num_shards=2)
    ps.register("w0")
    a = ps.pull()
    assert ps.pull_cache_hits == 0
    b = ps.pull()
    assert ps.pull_cache_hits == len(ps.flat)        # all leaves cached
    assert all(x is y for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    deltas = {"a": np.ones((8, 8), np.float32), "b": np.ones(16, np.float32)}
    ps.push_wave("w0", deltas)                       # bumps both shards
    c = ps.pull()
    assert not any(x is y for x, y in
                   zip(jax.tree.leaves(b), jax.tree.leaves(c)))
    np.testing.assert_allclose(np.asarray(c["a"]),
                               np.asarray(b["a"]) + 1.0)


def test_ps_begin_finish_push_split():
    """begin_push accounts and starts the wire without touching w_global;
    finish_push applies and advances the WSP clock."""
    ps = ParameterServer(_params(), D=0)
    ps.register("w0")
    before = [f.copy() for f in ps.flat]
    pending = ps.begin_push("w0", {"a": np.ones((8, 8), np.float32),
                                   "b": np.ones(16, np.float32)})
    assert ps.bytes_pushed > 0                       # accounted at begin
    for f, b in zip(ps.flat, before):
        np.testing.assert_array_equal(f, b)          # not applied yet
    assert ps.clock.state.clocks["w0"] == 0
    assert ps.finish_push(pending) == 1
    assert ps.clock.state.clocks["w0"] == 1
    assert float(ps.flat[0][0]) == pytest.approx(2.0)   # ones + delta
    with pytest.raises(AssertionError):
        ps.finish_push(pending)                      # double-finish rejected


CFG = reduced(ARCHS["qwen3-0.6b"], num_layers=2, d_model=32, d_ff=64,
              vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=16,
              num_microbatches=2)


def _setup():
    params, _ = lm.init_params(CFG, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", 0.3)
    step = build_local_wave_step(CFG, CFG.num_microbatches, opt)
    return params, opt, step


def test_trainer_topology_slows_wall_clock():
    """A 2-node heterogeneous topology (cross-node pushes/pulls pay Ethernet
    latency+bandwidth) must cost strictly more wall time than the
    zero-latency default, with per-link bytes accounted."""
    params, opt, step = _setup()
    kw = dict(num_vw=2, D=0, batch=4, seq=32, vocab=CFG.vocab_size,
              max_waves=3)
    WSPTrainer(params, step, opt, **kw).run()    # warm the jit cache
    base = WSPTrainer(params, step, opt, **kw).run()
    assert base.comm_seconds == 0.0
    slow_eth = LinkSpec("slow-eth", 0.05, 0.02)      # exaggerated for CI
    topo = ClusterTopology([Pod("node0", ("vw0",), NVLINK),
                            Pod("node1", ("vw1",), PCIE)], inter=slow_eth)
    tr = WSPTrainer(params, step, opt, topology=topo, **kw)
    rep = tr.run()
    assert rep.comm_seconds > 0.0
    assert rep.wall_s > base.wall_s
    assert rep.comm["bytes_by_link"].get("slow-eth", 0) > 0
    assert sum(rep.wait_seconds.values()) >= 0.0


def test_trainer_codec_and_topology_compose():
    params, opt, step = _setup()
    tr = WSPTrainer(params, step, opt, num_vw=2, D=0, batch=4, seq=32,
                    vocab=CFG.vocab_size, max_waves=3,
                    codec="topk:0.25", topology="2node", time_scale=0.0)
    rep = tr.run()
    assert rep.bytes_wire < rep.bytes_pushed
    assert rep.comm_seconds > 0.0                    # modeled even unscaled


def test_trainer_rejoin_with_topology_aliases_endpoint():
    """An elastically re-joined worker ('vw1r') is not a topology endpoint;
    the trainer must alias it onto the failed worker's node instead of the
    transport raising KeyError on its first pull."""
    params, opt, step = _setup()
    tr = WSPTrainer(params, step, opt, num_vw=2, D=1, batch=4, seq=32,
                    vocab=CFG.vocab_size, max_waves=4, fail_at={1: 1},
                    topology="2node", time_scale=0.0)
    tr.run(rejoin_failed_after=0.05)
    rejoined = [w for k, w in tr.workers.items() if k.endswith("r")]
    assert rejoined
    assert not any(w.failed for w in rejoined)
    assert tr.topology.link("vw1r", "ps").name == \
        tr.topology.link("vw1", "ps").name


def test_bsp_baseline_uses_ring_and_topology():
    params, opt, step = _setup()
    kw = dict(num_vw=2, batch=4, seq=32, vocab=CFG.vocab_size, max_waves=3)
    rep0 = bsp_allreduce_baseline(params, step, opt, **kw)
    rep1 = bsp_allreduce_baseline(params, step, opt, topology="2node", **kw)
    assert rep0.comm_seconds == 0.0
    assert rep1.comm_seconds > 0.0
    assert rep1.bytes_wire > 0 and rep1.bytes_pushed > rep1.bytes_wire / 2
    # simulated straggler-gated clock: monotone loss timestamps
    xs, _ = rep1.loss_curve()
    assert (np.diff(xs) >= 0).all()
