"""Subprocess body for serve-path parity on a real pipelined mesh (needs
4 fake devices, so it must own the process — XLA device count is locked at
first jax import).

Checks, per architecture family:
  1. build_prefill_step / build_decode_step logits match the forward_ref
     cache path to float tolerance on an aligned greedy rollout;
  2. Engine.generate() on the spmd backend produces bit-identical tokens
     to the threads (forward_ref) backend;
  3. the continuous-batching Scheduler produces identical per-request
     token streams on both backends (staggered per-row positions through
     the pipelined decode step);
  4. the same holds with a paged KV pool (page_size < prompt_len): block-
     table reads/writes through the pipeline scan reproduce the
     contiguous-degenerate streams bit for bit on both backends;
  5. with ServeSpec.share_prefix, repeated prompts served through
     refcounted shared pages (prefill skipping the matched prefix)
     reproduce the unshared paged streams bit for bit on both backends;
  6. with ServeSpec.kernel_backend="interpret" the Pallas kernels own the
     hot paths — paged decode walks the KV pool through the block table
     inside flash_decode_paged (per-row lengths, no gathered view) — and
     the token streams stay bit-identical to the jnp "ref" oracle, for
     plain paged, shared-prefix, and (full-attention) fp8 KV pools.

Run: python tests/serve_parity_main.py <arch> <seed>
"""
import os
import sys
from dataclasses import replace as dc_replace

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax                                   # noqa: E402
import jax.numpy as jnp                      # noqa: E402
import numpy as np                           # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.api import (Engine, PartitionSpec, Plan, RunSpec,  # noqa: E402
                       ServeSpec)
from repro.api.serving import Request, Scheduler  # noqa: E402
from repro.compat import set_mesh                 # noqa: E402
from repro.configs import ARCHS, reduced, RunConfig, ShapeConfig  # noqa: E402
from repro.core import wave                  # noqa: E402
from repro.launch.mesh import make_mesh_auto  # noqa: E402
from repro.models import lm                  # noqa: E402

PROMPT, GEN, B = 8, 6, 4


def _cfg(arch_name: str):
    over = {}
    if ARCHS[arch_name].attn_type == "swa":
        over["window_size"] = 6          # < max_len: exercise ring wrap
    return reduced(ARCHS[arch_name], stages=2, tp=2, num_layers=4,
                   num_microbatches=2, **over)


def step_level_parity(cfg, params, pspecs, prompts) -> None:
    """build_prefill_step/build_decode_step vs the forward_ref oracle."""
    mesh = make_mesh_auto((1, 2, 2), ("data", "stage", "tp"))
    max_len = PROMPT + GEN
    common = dict(arch=cfg, compute_dtype="float32")
    rc_pre = RunConfig(shape=ShapeConfig("p", PROMPT, B, "prefill"),
                       **common)
    rc_dec = RunConfig(shape=ShapeConfig("d", max_len, B, "decode"),
                       **common)
    pre_step, _, _ = wave.build_prefill_step(rc_pre, mesh, cache_len=max_len)
    dec_step, _, _ = wave.build_decode_step(rc_dec, mesh, pos_per_row=True)
    with set_mesh(mesh):
        p_sh = jax.device_put(params, jax.tree.map(
            lambda s: NamedSharding(mesh, s), pspecs,
            is_leaf=lambda x: isinstance(x, P)))
        cache = lm.init_cache(cfg, B, max_len, dtype=jnp.float32)
        logits, cache = jax.jit(pre_step)(p_sh, {"inputs": prompts,
                                                 "cache": cache})

    ref_cache = lm.init_cache(cfg, B, max_len, dtype=jnp.float32)
    hid, ref_cache, _ = lm.forward_ref(cfg, params, prompts, mode="prefill",
                                       cache=ref_cache)
    ref_logits = lm.logits_ref(cfg, params, hid[:, -1:])
    pd = float(jnp.max(jnp.abs(logits - ref_logits)))
    assert pd < 1e-3, f"prefill logits diff {pd}"
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    ref_tok = jnp.argmax(ref_logits[:, -1], axis=-1)
    assert np.array_equal(np.asarray(tok), np.asarray(ref_tok))

    dd = 0.0
    for t in range(1, GEN):
        pos = jnp.full((B,), PROMPT + t - 1, jnp.int32)
        with set_mesh(mesh):
            logits, cache = jax.jit(dec_step)(
                p_sh, {"inputs": tok[:, None], "cache": cache, "pos": pos})
        hid, ref_cache, _ = lm.forward_ref(
            cfg, params, ref_tok[:, None], mode="decode", cache=ref_cache,
            pos=jnp.int32(PROMPT + t - 1))
        ref_lg = lm.logits_ref(cfg, params, hid)
        dd = max(dd, float(jnp.max(jnp.abs(logits - ref_lg))))
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        ref_tok = jnp.argmax(ref_lg[:, -1], axis=-1)
        assert np.array_equal(np.asarray(tok), np.asarray(ref_tok)), \
            f"greedy tokens diverged at step {t}"
    print(f"step_logits_diff={dd:.3e}")


def main(arch_name: str, seed: int) -> int:
    cfg = _cfg(arch_name)
    params, pspecs = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, PROMPT)),
                          jnp.int32)

    step_level_parity(cfg, params, pspecs, prompts)

    # Engine-level parity: same Plan, spmd mesh vs threads (forward_ref)
    serve = ServeSpec(prompt_len=PROMPT, gen=GEN, max_batch=B)
    spmd = Plan(arch=cfg, serve=serve,
                partition=PartitionSpec(stages=2, tp=2, data=1),
                run=RunSpec(backend="spmd"))
    ref = Plan(arch=cfg, serve=serve)
    rep_s = Engine(spmd).generate(prompts)
    rep_r = Engine(ref).generate(prompts)
    assert np.array_equal(rep_s.tokens, rep_r.tokens), \
        (rep_s.tokens, rep_r.tokens)
    print("generate_tokens_identical=1")

    # Scheduler parity: staggered admissions drive the per-row position
    # vector through the pipelined decode step
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, PROMPT,
                                        dtype=np.int32),
                    max_new_tokens=int(rng.integers(2, GEN + 1)))
            for i in range(2 * B)]
    out_s = Scheduler(Engine(spmd)).run(list(reqs))
    out_r = Scheduler(Engine(ref)).run(list(reqs))
    for a, b in zip(out_s.requests, out_r.requests):
        assert a.rid == b.rid and a.tokens == b.tokens, (a.rid, a.tokens,
                                                         b.tokens)
    assert out_s.tokens_out == sum(r.max_new_tokens for r in reqs)
    print("scheduler_tokens_identical=1")

    # Paged parity: page_size < prompt_len splits every slot's KV across
    # pages; streams must match the contiguous-degenerate runs above on
    # both backends
    paged = ServeSpec(prompt_len=PROMPT, gen=GEN, max_batch=B, page_size=4)
    out_ps = Scheduler(Engine(spmd.replace(serve=paged))).run(list(reqs))
    out_pr = Scheduler(Engine(ref.replace(serve=paged))).run(list(reqs))
    for a, b, c in zip(out_ps.requests, out_pr.requests, out_r.requests):
        assert a.rid == b.rid == c.rid
        assert a.tokens == b.tokens == c.tokens, (a.rid, a.tokens, b.tokens,
                                                  c.tokens)
    if cfg.attn_type == "full":
        assert out_ps.pages_total == out_pr.pages_total > B  # really paged
    else:
        # all-windowed / attention-free: no full-attention KV group, so
        # no page pool to ration (fixed-size per-slot state only)
        assert out_ps.pages_total == out_pr.pages_total == 0
    print("paged_scheduler_tokens_identical=1")

    # Shared-prefix paged parity: every even rid repeats rid 0's prompt,
    # so the prefix index maps them onto shared refcounted pages and
    # prefill skips the matched writes — streams must still match the
    # unshared paged run bit for bit on both backends
    s_reqs = [Request(rid=i,
                      prompt=(reqs[0] if i % 2 == 0 else reqs[i])
                      .prompt.copy(),
                      max_new_tokens=reqs[i].max_new_tokens)
              for i in range(2 * B)]
    shared = ServeSpec(prompt_len=PROMPT, gen=GEN, max_batch=B, page_size=4,
                       share_prefix=True)
    out_ss = Scheduler(Engine(spmd.replace(serve=shared))).run(
        [Request(r.rid, r.prompt.copy(), r.max_new_tokens) for r in s_reqs])
    out_sr = Scheduler(Engine(ref.replace(serve=shared))).run(
        [Request(r.rid, r.prompt.copy(), r.max_new_tokens) for r in s_reqs])
    out_ur = Scheduler(Engine(ref.replace(serve=paged))).run(s_reqs)
    for a, b, c in zip(out_ss.requests, out_sr.requests, out_ur.requests):
        assert a.rid == b.rid == c.rid
        assert a.tokens == b.tokens == c.tokens, (a.rid, a.tokens, b.tokens,
                                                  c.tokens)
    if cfg.attn_type == "full":
        # the page accounting is backend-independent too (peak contrasts
        # vs unshared live in benchmarks/serve_bench.py's squeezed pool)
        assert out_ss.prefix_hit_tokens > 0
        assert out_ss.prefix_hit_tokens == out_sr.prefix_hit_tokens
        assert out_ss.peak_pages == out_sr.peak_pages
        assert out_ss.pages_shared == out_sr.pages_shared > 0
    else:
        assert out_ss.prefix_hit_tokens == out_sr.prefix_hit_tokens == 0
    print("shared_prefix_tokens_identical=1")

    # Kernel-backend parity: the same staggered request mix through the
    # Pallas kernels in interpret mode (threads backend; decode consumes
    # the paged pool + block table directly inside flash_decode_paged with
    # per-row lengths). Streams must match the jnp "ref" runs bit for bit.
    interp = dc_replace(paged, kernel_backend="interpret")
    out_ki = Scheduler(Engine(ref.replace(serve=interp))).run(list(reqs))
    for a, b in zip(out_ki.requests, out_pr.requests):
        assert a.rid == b.rid and a.tokens == b.tokens, (a.rid, a.tokens,
                                                         b.tokens)
    out_ks = Scheduler(Engine(ref.replace(
        serve=dc_replace(shared, kernel_backend="interpret")))).run(
        [Request(r.rid, r.prompt.copy(), r.max_new_tokens) for r in s_reqs])
    for a, b in zip(out_ks.requests, out_sr.requests):
        assert a.rid == b.rid and a.tokens == b.tokens, (a.rid, a.tokens,
                                                         b.tokens)
    if cfg.attn_type == "full":
        # fp8 KV pages quantize both backends identically (the kernel
        # reads the pool pages as stored, casting in-register)
        f8 = dc_replace(paged, cache_dtype="f8")
        out_f8r = Scheduler(Engine(ref.replace(serve=f8))).run(list(reqs))
        out_f8i = Scheduler(Engine(ref.replace(
            serve=dc_replace(f8, kernel_backend="interpret")))).run(
            list(reqs))
        for a, b in zip(out_f8i.requests, out_f8r.requests):
            assert a.rid == b.rid and a.tokens == b.tokens, (a.rid,
                                                             a.tokens,
                                                             b.tokens)
    print("kernel_backend_tokens_identical=1")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 0))
