"""The repro.api experiment layer: Plan validation, SyncPolicy dispatch,
Engine parity with the legacy constructors, presets, checkpoint atomicity
under async push, and the CLI routing through the Engine."""
import os
import tempfile
import threading
import types

import jax
import numpy as np
import pytest

from repro.api import (ASP, BSP, ClusterSpec, Engine, PartitionSpec, Plan,
                       RunSpec, TrainReport, UNBOUNDED_D, WSP, get_preset,
                       list_presets)
from repro.configs import ARCHS, reduced
from repro.core.param_server import ParameterServer
from repro.core.wave import build_local_wave_step
from repro.models import lm
from repro.optim import make_optimizer
from repro.runtime.checkpoint import latest_checkpoint, load_checkpoint

CFG = reduced(ARCHS["qwen3-0.6b"], num_layers=2, d_model=32, d_ff=64,
              vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=16,
              num_microbatches=2)


def _setup(lr=0.3):
    params, _ = lm.init_params(CFG, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", lr)
    step = build_local_wave_step(CFG, CFG.num_microbatches, opt)
    return params, opt, step


# ---------------------------------------------------------------------------
# Plan validation (fail where the scenario is written)
# ---------------------------------------------------------------------------
def test_plan_validates_at_construction():
    with pytest.raises(ValueError, match="D must be"):
        Plan(sync=WSP(D=-1))
    with pytest.raises(ValueError, match="pull_every"):
        Plan(sync=WSP(pull_every=0))
    with pytest.raises(ValueError, match="num_vw"):
        Plan(cluster=ClusterSpec(num_vw=0))
    with pytest.raises(ValueError, match="speeds has"):
        Plan(cluster=ClusterSpec(num_vw=2, speeds=(0.1,)))
    with pytest.raises(ValueError, match="unknown backend"):
        Plan(run=RunSpec(backend="mpi"))
    with pytest.raises(ValueError, match="two spellings"):
        Plan(run=RunSpec(codec="int8", compression_ratio=0.5))
    with pytest.raises(ValueError, match="unknown codec"):
        Plan(run=RunSpec(codec="zstd"))
    with pytest.raises(ValueError, match="topology"):
        Plan(cluster=ClusterSpec(num_vw=2, topology="bogus-spec"))
    with pytest.raises(ValueError, match="not divisible"):
        Plan(arch=CFG, run=RunSpec(batch=5))
    with pytest.raises(ValueError, match="outside the fleet"):
        Plan(cluster=ClusterSpec(num_vw=2, fail_at={5: 3}))


def test_plan_validates_spmd_mesh():
    with pytest.raises(ValueError, match="arch is required|Plan.arch"):
        Plan(run=RunSpec(backend="spmd"))
    # stages*tp must divide the device count
    with pytest.raises(ValueError, match="does not divide"):
        Plan(arch=CFG, run=RunSpec(backend="spmd"),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=3))
    with pytest.raises(ValueError, match="data\\*stages\\*tp"):
        Plan(arch=CFG, run=RunSpec(backend="spmd"),
             partition=PartitionSpec(stages=2, tp=1, data=2, devices=8))
    # the jitted path is D=0 by construction
    with pytest.raises(ValueError, match="D = 0"):
        Plan(arch=CFG, sync=WSP(D=2), run=RunSpec(backend="spmd"),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))
    with pytest.raises(ValueError, match="async_push"):
        Plan(arch=CFG, sync=WSP(D=0, async_push=True),
             run=RunSpec(backend="spmd"),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))


def test_plan_rejects_knobs_the_backend_would_drop():
    # BSP all-reduces raw deltas: no codec, no per-worker failure injection
    with pytest.raises(ValueError, match="BSP loop all-reduces"):
        Plan(sync=BSP(), run=RunSpec(codec="topk:0.25"))
    with pytest.raises(ValueError, match="speeds only"):
        Plan(sync=BSP(), cluster=ClusterSpec(num_vw=2, fail_at={0: 1}))
    # the jitted spmd backend reduces in-graph: no PS-path modeling
    with pytest.raises(ValueError, match="reduces in-graph"):
        Plan(arch=CFG, run=RunSpec(backend="spmd", codec="int8"),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))
    with pytest.raises(ValueError, match="reduces in-graph"):
        Plan(arch=CFG, run=RunSpec(backend="spmd"),
             cluster=ClusterSpec(num_vw=1, topology="2node"),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))
    with pytest.raises(ValueError, match="threaded fleet"):
        Plan(arch=CFG, run=RunSpec(backend="spmd"),
             cluster=ClusterSpec(num_vw=2, speeds=(0.0, 0.5)),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))
    # the threads backend never factors a mesh
    with pytest.raises(ValueError, match="spmd mesh"):
        Plan(arch=CFG, partition=PartitionSpec(stages=4, data=2))
    # an explicit shape must agree with the run's loader shapes
    from repro.configs import ShapeConfig
    with pytest.raises(ValueError, match="disagrees"):
        Plan(arch=CFG, shape=ShapeConfig("x", 128, 8, "train"),
             run=RunSpec(backend="spmd", seq=64, batch=4),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))


def test_plan_replace_nested():
    plan = Plan(arch=CFG, sync=WSP(D=1))
    p2 = plan.replace(sync__D=3, run__max_waves=7,
                      cluster=ClusterSpec(num_vw=4))
    assert (p2.sync.D, p2.run.max_waves, p2.cluster.num_vw) == (3, 7, 4)
    assert plan.sync.D == 1                    # original untouched (frozen)


def test_asp_is_unbounded_wsp():
    assert isinstance(ASP(), WSP)
    assert ASP().D == UNBOUNDED_D
    assert "inf" in ASP().describe()


# ---------------------------------------------------------------------------
# TrainReport.loss_curve regression: sort by wall clock only
# ---------------------------------------------------------------------------
def test_loss_curve_sorts_by_time_only():
    """Tuple-sorting fell through to the worker id on wall-clock ties; with
    mixed-type ids that raised TypeError, and with string ids it reordered
    losses by name rather than time."""
    rep = TrainReport(losses=[(1.0, "vw9", 3.0), (1.0, 2, 4.0),
                              (0.5, "vw1", 5.0)])
    xs, ys = rep.loss_curve()                  # must not raise
    assert list(xs) == [0.5, 1.0, 1.0]
    assert ys[0] == 5.0
    # stable for ties: original append order preserved
    assert list(ys[1:]) == [3.0, 4.0]


# ---------------------------------------------------------------------------
# parity: the legacy constructors are shims over the same Engine
# ---------------------------------------------------------------------------
def test_engine_matches_legacy_wsp_trainer():
    """Engine.fit() with SyncPolicy=WSP(D) and the deprecated
    WSPTrainer.run() produce identical loss curves and final PS params on a
    seeded single-worker config (single worker => fully deterministic)."""
    from repro.runtime.trainer import WSPTrainer
    params, opt, step = _setup()
    plan = Plan(cluster=ClusterSpec(num_vw=1),
                sync=WSP(D=1, pull_every=2),
                run=RunSpec(max_waves=6, batch=8, seq=32,
                            vocab=CFG.vocab_size))
    eng = Engine(plan, params=params, wave_step=step, optimizer=opt)
    rep_new = eng.fit()
    with pytest.deprecated_call():
        tr = WSPTrainer(params, step, opt, num_vw=1, D=1, pull_every=2,
                        batch=8, seq=32, vocab=CFG.vocab_size, max_waves=6)
    rep_old = tr.run()
    np.testing.assert_array_equal(rep_new.loss_curve()[1],
                                  rep_old.loss_curve()[1])
    for a, b in zip(eng.ps.flat, tr.ps.flat):
        np.testing.assert_array_equal(a, b)


def test_engine_matches_legacy_bsp_baseline():
    from repro.runtime.trainer import bsp_allreduce_baseline
    params, opt, step = _setup()
    plan = Plan(cluster=ClusterSpec(num_vw=2), sync=BSP(),
                run=RunSpec(max_waves=5, batch=8, seq=32,
                            vocab=CFG.vocab_size))
    rep_new = Engine(plan, params=params, wave_step=step,
                     optimizer=opt).fit()
    with pytest.deprecated_call():
        rep_old = bsp_allreduce_baseline(params, step, opt, num_vw=2,
                                         batch=8, seq=32,
                                         vocab=CFG.vocab_size, max_waves=5)
    np.testing.assert_array_equal(rep_new.loss_curve()[1],
                                  rep_old.loss_curve()[1])


def test_threads_fit_is_single_shot():
    params, opt, step = _setup()
    plan = Plan(cluster=ClusterSpec(num_vw=1), sync=WSP(D=1),
                run=RunSpec(max_waves=2, batch=8, seq=32,
                            vocab=CFG.vocab_size))
    eng = Engine(plan, params=params, wave_step=step, optimizer=opt)
    eng.fit()
    with pytest.raises(RuntimeError, match="already ran"):
        eng.fit()                 # would return an empty report otherwise


def test_bsp_checkpoints_and_resumes():
    """The BSP loop honors ckpt_dir/ckpt_every/resume like the other
    backends (checkpoint at the cadence AND at end of run, numbering
    continued across resume)."""
    def unit_step(params, opt_state, x, y):
        return {"w": np.ones(4, np.float32)}, opt_state, 1.0

    opt = types.SimpleNamespace(init=lambda p: None)
    with tempfile.TemporaryDirectory() as d:
        plan = Plan(cluster=ClusterSpec(num_vw=2), sync=BSP(),
                    run=RunSpec(max_waves=3, batch=2, seq=8, vocab=16,
                                ckpt_dir=d, ckpt_every=2))
        Engine(plan, params={"w": np.zeros(4, np.float32)},
               wave_step=unit_step, optimizer=opt).fit()
        # wave 2 (cadence) and wave 3 (end of run, off-cadence)
        assert sorted(os.listdir(d)) == ["step_00000002", "step_00000003"]
        Engine(plan.replace(run__resume=True, run__max_waves=2),
               params={"w": np.zeros(4, np.float32)},
               wave_step=unit_step, optimizer=opt).fit()
        out, meta = load_checkpoint(latest_checkpoint(d),
                                    {"params": {"w": np.zeros(4)}})
        assert meta["step"] == 5   # numbering continued: 3 restored + 2 new
        # averaged unit deltas: +1 per wave, so weights == total waves
        np.testing.assert_array_equal(out["params"]["w"], np.full(4, 5.0))
        # explicit save() also carries the continued numbering (not step 0)
        eng = Engine(plan.replace(run__resume=True, run__max_waves=1,
                                  run__ckpt_every=0),
                     params={"w": np.zeros(4, np.float32)},
                     wave_step=unit_step, optimizer=opt)
        eng.fit()
        assert eng.save().endswith("step_00000006")


def test_bsp_rejects_rejoin():
    params, opt, step = _setup()
    plan = Plan(cluster=ClusterSpec(num_vw=2), sync=BSP(),
                run=RunSpec(max_waves=2, batch=8, seq=32,
                            vocab=CFG.vocab_size))
    with pytest.raises(ValueError, match="no PS"):
        Engine(plan, params=params, wave_step=step,
               optimizer=opt).fit(rejoin_failed_after=0.1)
    # same contract on the spmd backend: unsupported, so loud
    spmd_plan = Plan(arch=CFG, sync=WSP(D=0),
                     partition=PartitionSpec(stages=2, tp=1, data=1,
                                             devices=2),
                     run=RunSpec(backend="spmd", max_waves=1))
    with pytest.raises(ValueError, match="no workers to rejoin"):
        Engine(spmd_plan).fit(rejoin_failed_after=0.1)


def test_asp_fast_worker_never_gated():
    params, opt, step = _setup()
    plan = Plan(cluster=ClusterSpec(num_vw=2, speeds=(0.0, 0.08)),
                sync=ASP(),
                run=RunSpec(max_waves=4, batch=4, seq=32,
                            vocab=CFG.vocab_size))
    rep = Engine(plan, params=params, wave_step=step, optimizer=opt).fit()
    assert rep.wait_seconds["vw0"] < 0.05      # gate disabled at D=inf


def test_engine_step_api():
    params, opt, step = _setup()
    plan = Plan(cluster=ClusterSpec(num_vw=1), sync=WSP(D=1),
                run=RunSpec(max_waves=3, batch=8, seq=32,
                            vocab=CFG.vocab_size))
    eng = Engine(plan, params=params, wave_step=step, optimizer=opt)
    losses = [eng.step() for _ in range(3)]
    assert all(isinstance(l, float) for l in losses)
    assert eng.ps.clock.state.clocks == {"vw0": 3}


def test_engine_requires_arch_or_injection():
    with pytest.raises(ValueError, match="inject"):
        Engine(Plan())


def test_step_matches_fit_including_pull_every():
    """Driving a Plan wave-by-wave through step() must reproduce fit()'s
    loss sequence and final PS params exactly, including the pull_every
    weight handling (single worker => fully deterministic)."""
    params, opt, step = _setup()
    plan = Plan(cluster=ClusterSpec(num_vw=1), sync=WSP(D=1, pull_every=2),
                run=RunSpec(max_waves=4, batch=8, seq=32,
                            vocab=CFG.vocab_size))
    eng_fit = Engine(plan, params=params, wave_step=step, optimizer=opt)
    rep = eng_fit.fit()
    eng_step = Engine(plan, params=params, wave_step=step, optimizer=opt)
    losses = [eng_step.step() for _ in range(4)]
    np.testing.assert_array_equal(np.asarray(losses), rep.loss_curve()[1])
    for a, b in zip(eng_step.ps.flat, eng_fit.ps.flat):
        np.testing.assert_array_equal(a, b)


def test_engine_step_rejects_bsp():
    """step() must not silently substitute a WSP policy for a BSP plan
    (fit() and step() on one Plan must agree on the synchronization
    model)."""
    params, opt, step = _setup()
    plan = Plan(cluster=ClusterSpec(num_vw=2), sync=BSP(),
                run=RunSpec(max_waves=2, batch=8, seq=32,
                            vocab=CFG.vocab_size))
    eng = Engine(plan, params=params, wave_step=step, optimizer=opt)
    with pytest.raises(ValueError, match="fit"):
        eng.step()


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------
def test_presets_all_build_valid_plans():
    names = set(list_presets())
    assert {"single_node", "paper_hetero", "whimpy_1gbe",
            "bsp_baseline", "spmd_tiny"} <= names
    for name in names:
        plan = get_preset(name)
        assert isinstance(plan, Plan)          # validated at construction
    with pytest.raises(KeyError, match="unknown preset"):
        get_preset("nope")


def test_preset_override_and_run():
    plan = get_preset("single_node", run__max_waves=4, sync__D=0)
    assert plan.run.max_waves == 4 and plan.sync.D == 0
    rep = Engine(plan).fit()
    assert rep.waves == 8                      # 2 workers x 4 waves


# ---------------------------------------------------------------------------
# checkpointing under async push (satellite: in-flight pushes must be
# atomic with respect to snapshots)
# ---------------------------------------------------------------------------
def test_ps_snapshot_atomic_with_concurrent_pushes():
    """checkpoint_state() must capture weights containing exactly the waves
    the clocks count: with unit deltas, snapshot weights == sum of clocks,
    always. Without the PS snapshot lock a push could land between the
    weight copy and the clock copy (push lost on resume) or vice versa
    (double-applied)."""
    ps = ParameterServer({"w": np.zeros(64, np.float32)}, D=UNBOUNDED_D)
    delta = {"w": np.ones(64, np.float32)}
    for wid in ("vw0", "vw1"):
        ps.register(wid)

    def pusher(wid):
        for _ in range(40):
            ps.push_wave(wid, delta)

    threads = [threading.Thread(target=pusher, args=(w,))
               for w in ("vw0", "vw1")]
    for t in threads:
        t.start()
    violations = []
    while any(t.is_alive() for t in threads):
        snap, meta = ps.checkpoint_state()
        want = float(meta["push_count"])
        got = np.asarray(snap["w"])
        if not np.all(got == want):
            violations.append((want, float(got[0])))
    for t in threads:
        t.join()
    assert not violations, violations[:5]
    assert ps.clock.state.clocks == {"vw0": 40, "vw1": 40}
    assert ps.push_count == 80


def test_checkpoint_not_lost_or_doubled_under_async_push(tmp_path=None):
    """End-to-end: periodic checkpoints taken while async outbox pushes are
    in flight (slow simulated link). Every checkpoint written must satisfy
    weights == sum(clock) * unit-delta, and resuming from the latest one
    continues exactly."""
    from repro.dist.topology import ClusterTopology, LinkSpec, NVLINK, Pod
    slow = LinkSpec("slow", 1e6, 0.02)         # ~20ms per push in flight
    topo = ClusterTopology([Pod("node0", ("vw0",), NVLINK),
                            Pod("node1", ("vw1",), NVLINK)], inter=slow)

    def unit_step(params, opt_state, x, y):
        return {"w": np.ones(8, np.float32)}, opt_state, 1.0

    opt = types.SimpleNamespace(init=lambda p: None)
    with tempfile.TemporaryDirectory() as d:
        plan = Plan(cluster=ClusterSpec(num_vw=2, topology=topo,
                                        time_scale=1.0),
                    sync=WSP(D=4, pull_every=2, async_push=True),
                    run=RunSpec(max_waves=6, batch=2, seq=8, vocab=16,
                                ckpt_dir=d, ckpt_every=1))
        eng = Engine(plan, params={"w": np.zeros(8, np.float32)},
                     wave_step=unit_step, optimizer=opt)
        eng.fit()
        steps = sorted(p for p in os.listdir(d) if p.startswith("step_"))
        assert steps, "no periodic checkpoints written"
        for s in steps:
            out, meta = load_checkpoint(
                os.path.join(d, s), {"params": {"w": np.zeros(8)}})
            # weights contain exactly the pushes the meta counts — an
            # in-flight push is either fully in (weights AND count) or
            # fully out, never half
            want = float(meta["push_count"])
            np.testing.assert_array_equal(out["params"]["w"],
                                          np.full(8, want))
        # resume from the latest checkpoint and push two more waves each
        plan2 = plan.replace(run__max_waves=2, run__resume=True,
                             run__ckpt_every=0)
        eng2 = Engine(plan2, params={"w": np.zeros(8, np.float32)},
                      wave_step=unit_step, optimizer=opt)
        eng2.fit()
        _, meta = load_checkpoint(latest_checkpoint(d),
                                  {"params": {"w": np.zeros(8)}})
        restored = float(meta["push_count"])
        np.testing.assert_array_equal(
            eng2.ps.flat[0], np.full(8, restored + 4.0, np.float32))


def test_resume_checkpoint_numbering_monotone():
    """Post-resume checkpoints must continue the restored step numbering:
    if they restarted at zero, latest_checkpoint() would resolve to the
    stale pre-resume checkpoint and discard all post-resume progress.
    With unit deltas, every checkpoint's weights == its step number."""
    def unit_step(params, opt_state, x, y):
        return {"w": np.ones(4, np.float32)}, opt_state, 1.0

    opt = types.SimpleNamespace(init=lambda p: None)
    with tempfile.TemporaryDirectory() as d:
        plan = Plan(cluster=ClusterSpec(num_vw=1), sync=WSP(D=1),
                    run=RunSpec(max_waves=3, batch=2, seq=8, vocab=16,
                                ckpt_dir=d, ckpt_every=1))
        Engine(plan, params={"w": np.zeros(4, np.float32)},
               wave_step=unit_step, optimizer=opt).fit()
        first = latest_checkpoint(d)
        Engine(plan.replace(run__resume=True, run__max_waves=2),
               params={"w": np.zeros(4, np.float32)},
               wave_step=unit_step, optimizer=opt).fit()
        assert latest_checkpoint(d) > first        # numbering continued
        for s in sorted(os.listdir(d)):
            step = int(s.removeprefix("step_"))
            out, _ = load_checkpoint(os.path.join(d, s),
                                     {"params": {"w": np.zeros(4)}})
            np.testing.assert_array_equal(out["params"]["w"],
                                          np.full(4, float(step)))


def test_spmd_resume_with_repartitioned_stages():
    """The spmd backend re-factors stages from the PartitionSpec; the
    resume path must build its checkpoint template from that same arch
    (padded layer counts differ when stages does not divide num_layers)."""
    cfg3 = reduced(ARCHS["qwen3-0.6b"], num_layers=3, d_model=32, d_ff=64,
                   vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=16,
                   num_microbatches=2, stages=2)
    assert cfg3.padded_layers == 4                 # 2 stages pad 3 -> 4
    with tempfile.TemporaryDirectory() as d:
        plan = Plan(arch=cfg3,
                    partition=PartitionSpec(stages=1, tp=1, data=1),
                    sync=WSP(D=0),
                    run=RunSpec(backend="spmd", max_waves=1, batch=4,
                                seq=16, ckpt_dir=d, ckpt_every=1))
        Engine(plan).fit()                         # 1-stage arch: 3 layers
        eng2 = Engine(plan.replace(run__resume=True))
        eng2.fit()                                 # restore must not reshape
        assert eng2._step_offset == 1
        assert latest_checkpoint(d).endswith("step_00000002")


def test_engine_save_restore_roundtrip():
    params, opt, step = _setup()
    with tempfile.TemporaryDirectory() as d:
        plan = Plan(cluster=ClusterSpec(num_vw=1), sync=WSP(D=1),
                    run=RunSpec(max_waves=3, batch=8, seq=32,
                                vocab=CFG.vocab_size, ckpt_dir=d))
        eng = Engine(plan, params=params, wave_step=step, optimizer=opt)
        eng.fit()
        path = eng.save()
        trained = [f.copy() for f in eng.ps.flat]
        eng2 = Engine(plan, params=params, wave_step=step, optimizer=opt)
        meta = eng2.restore(path)
        assert meta["clocks"] == {"vw0": 3}
        eng2._ensure_ps(plan.sync)
        for a, b in zip(eng2.ps.flat, trained):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# CLI: both launch modes route through the same Engine
# ---------------------------------------------------------------------------
def test_launch_train_wsp_routes_through_engine(capsys):
    from repro.launch import train
    train.main(["--mode", "wsp", "--reduced", "--layers", "2",
                "--d-model", "32", "--waves", "2", "--num-vw", "1",
                "--D", "0", "--batch", "4", "--seq", "32"])
    out = capsys.readouterr().out
    assert "waves=2" in out and "last_loss=" in out


def test_launch_train_spmd_routes_through_engine(capsys):
    # mesh 1,1,1 fits the single CPU device of the pytest process
    from repro.launch import train
    train.main(["--mode", "spmd", "--reduced", "--layers", "2",
                "--d-model", "32", "--waves", "2", "--mesh", "1,1,1",
                "--batch", "4", "--seq", "32"])
    out = capsys.readouterr().out
    assert "mesh=(1,1,1)" in out and "wave " in out


def test_launch_topology_list(capsys):
    from repro.launch import train
    train.main(["--topology", "list"])
    out = capsys.readouterr().out
    assert "<k>node[:LINK]" in out and "paper" in out


# ---------------------------------------------------------------------------
# make_topology validation (satellite)
# ---------------------------------------------------------------------------
def test_make_topology_helpful_errors():
    from repro.dist.topology import ETH_1G, IB_100G, make_topology
    with pytest.raises(ValueError, match="Known specs"):
        make_topology("bogus", 2)
    with pytest.raises(ValueError, match="integer k"):
        make_topology("xnode", 2)
    with pytest.raises(ValueError, match="unknown inter-node link"):
        make_topology("2node:foo", 2)
    with pytest.raises(ValueError, match="at least one node"):
        make_topology("0node", 2)
    assert make_topology("2node:eth1", 4).inter is ETH_1G
    assert make_topology("2node:ib", 4).inter is IB_100G
    assert make_topology("none", 4) is None
