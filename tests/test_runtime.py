"""Threaded multi-VW WSP runtime: convergence, stragglers, checkpoint/restart,
elastic fail/rejoin, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.core.wave import build_local_wave_step
from repro.models import lm
from repro.optim import make_optimizer
from repro.runtime.checkpoint import (save_checkpoint, load_checkpoint,
                                      latest_checkpoint)
from repro.runtime.trainer import WSPTrainer, bsp_allreduce_baseline

CFG = reduced(ARCHS["qwen3-0.6b"], num_layers=2, d_model=32, d_ff=64,
              vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=16,
              num_microbatches=2)


def _setup(lr=0.3):
    params, _ = lm.init_params(CFG, jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", lr)
    step = build_local_wave_step(CFG, CFG.num_microbatches, opt)
    return params, opt, step


def _final_loss(report, last=8):
    xs, ys = report.loss_curve()
    return float(np.mean(ys[-last:]))


def test_wsp_trainer_converges():
    params, opt, step = _setup()
    tr = WSPTrainer(params, step, opt, num_vw=2, D=1, batch=8, seq=32,
                    vocab=CFG.vocab_size, max_waves=12)
    rep = tr.run()
    xs, ys = rep.loss_curve()
    assert len(ys) >= 20
    assert _final_loss(rep) < ys[0] - 0.3       # real learning happened
    assert rep.bytes_pushed > 0


def test_wsp_straggler_d_allows_progress():
    """With a slow VW, D=2 lets the fast VW run ahead (its wave count beats
    the slow one's), while D=0 keeps them in lock step."""
    params, opt, step = _setup()
    for D, expect_ahead in ((0, False), (2, True)):
        tr = WSPTrainer(params, step, opt, num_vw=2, D=D, batch=4, seq=32,
                        vocab=CFG.vocab_size, max_waves=8,
                        speeds=[0.0, 0.12])
        tr.run()
        clocks = tr.ps.clock.state.clocks
        gap = abs(clocks["vw0"] - clocks["vw1"])
        if expect_ahead:
            assert tr.ps.clock.wait_seconds["vw0"] < 2.0
        else:
            assert gap <= 1


def test_bsp_baseline_converges():
    params, opt, step = _setup()
    rep = bsp_allreduce_baseline(params, step, opt, num_vw=2, batch=8,
                                 seq=32, vocab=CFG.vocab_size, max_waves=12)
    xs, ys = rep.loss_curve()
    assert _final_loss(rep) < ys[0] - 0.3


def test_elastic_fail_and_rejoin():
    params, opt, step = _setup()
    tr = WSPTrainer(params, step, opt, num_vw=3, D=1, batch=4, seq=32,
                    vocab=CFG.vocab_size, max_waves=8, fail_at={2: 2})
    rep = tr.run(rejoin_failed_after=0.2)
    # survivors finished their waves despite vw2 dying at wave 2
    assert tr.workers["vw2"].failed
    assert tr.ps.clock.state.clocks["vw0"] == 8
    assert tr.ps.clock.state.clocks["vw1"] == 8
    # the re-joined worker registered at the global clock and either made
    # progress or (under CPU contention) joined after the fleet finished —
    # in which case its clock equals the target
    rejoined = [w for k, w in tr.workers.items() if k.endswith("r")]
    assert rejoined
    rj = rejoined[0]
    clock = tr.ps.clock.state.clocks.get(rj.wid)
    assert rj.metrics.waves > 0 or clock == 8, (rj.metrics.waves, clock)


def test_async_push_matches_blocking_runtime():
    """Single-VW determinism: the async-push runtime (outbox thread, clock
    advanced at push-land time) must reproduce the blocking runtime's WSP
    clock trace, loss sequence, and final PS params exactly at
    time_scale=0-equivalent conditions."""
    params, opt, step = _setup()
    reps, trs = {}, {}
    for mode in (False, True):
        tr = WSPTrainer(params, step, opt, num_vw=1, D=1, batch=4, seq=32,
                        vocab=CFG.vocab_size, max_waves=6, pull_every=2,
                        async_push=mode)
        reps[mode] = tr.run()
        trs[mode] = tr
    assert trs[True].ps.clock.state.clocks == trs[False].ps.clock.state.clocks
    assert reps[True].waves == reps[False].waves == 6
    np.testing.assert_array_equal(reps[True].loss_curve()[1],
                                  reps[False].loss_curve()[1])
    for a, b in zip(trs[True].ps.flat, trs[False].ps.flat):
        np.testing.assert_array_equal(a, b)


def test_async_push_multi_vw_converges_and_overlaps():
    """Two async VWs over a simulated heterogeneous network: training still
    converges, every wave lands (clocks reach max_waves), and part of the
    push time is hidden under the next wave's compute."""
    from repro.dist.topology import ClusterTopology, LinkSpec, Pod, NVLINK
    params, opt, step = _setup()
    slow_eth = LinkSpec("slow-eth", 0.05, 0.01)
    topo = ClusterTopology([Pod("node0", ("vw0",), NVLINK),
                            Pod("node1", ("vw1",), NVLINK)], inter=slow_eth)
    tr = WSPTrainer(params, step, opt, num_vw=2, D=2, batch=8, seq=32,
                    vocab=CFG.vocab_size, max_waves=12, pull_every=4,
                    topology=topo, time_scale=1.0, speeds=[0.02, 0.02],
                    async_push=True)
    rep = tr.run()
    assert tr.ps.clock.state.clocks == {"vw0": 12, "vw1": 12}
    assert _final_loss(rep) < rep.loss_curve()[1][0] - 0.3
    assert rep.overlap_seconds > 0.0          # some comm was hidden
    assert rep.comm_seconds > 0.0


def test_async_push_respects_staleness_gate():
    """With D=0 the async runtime degenerates to lock step: neither worker
    may run a wave ahead even though pushes land off-thread — the fast
    worker provably blocks at the gate waiting for the slow one."""
    params, opt, step = _setup()
    tr = WSPTrainer(params, step, opt, num_vw=2, D=0, batch=4, seq=32,
                    vocab=CFG.vocab_size, max_waves=6, async_push=True,
                    speeds=[0.0, 0.05])
    tr.run()
    clocks = tr.ps.clock.state.clocks
    assert clocks == {"vw0": 6, "vw1": 6}
    # vw1 sleeps 0.05 s/wave; under D=0 lock step vw0 must absorb most of
    # that at the gate — if gating were broken vw0 would never wait
    assert tr.ps.clock.wait_seconds["vw0"] > 0.1


def test_compression_error_feedback_converges():
    params, opt, step = _setup(lr=0.3)
    tr = WSPTrainer(params, step, opt, num_vw=2, D=0, batch=8, seq=32,
                    vocab=CFG.vocab_size, max_waves=12,
                    compression_ratio=0.25)
    rep = tr.run()
    xs, ys = rep.loss_curve()
    assert _final_loss(rep) < ys[0] - 0.2
    assert rep.bytes_wire < 0.7 * rep.bytes_pushed   # wire savings real


def test_checkpoint_roundtrip_exact():
    params, _ = lm.init_params(CFG, jax.random.PRNGKey(1))
    opt = make_optimizer("adamw", 1e-3)
    state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 3, {"params": params, "opt": state},
                               {"note": "t"})
        assert latest_checkpoint(d) == path
        out, meta = load_checkpoint(path, {"params": params, "opt": state})
        assert meta["step"] == 3
        for a, b in zip(jax.tree.leaves(out["params"]),
                        jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trainer_checkpoint_restart_continuity():
    """Kill training at wave k, restore PS state, continue — the restored
    PS weights equal the checkpointed ones exactly."""
    params, opt, step = _setup()
    with tempfile.TemporaryDirectory() as d:
        tr = WSPTrainer(params, step, opt, num_vw=2, D=0, batch=4, seq=32,
                        vocab=CFG.vocab_size, max_waves=6,
                        ckpt_dir=d, ckpt_every=2)
        tr.run()
        path = latest_checkpoint(d)
        assert path is not None
        out, meta = load_checkpoint(path, {"params": params})
        tr2 = WSPTrainer(out["params"], step, opt, num_vw=2, D=0, batch=4,
                         seq=32, vocab=CFG.vocab_size, max_waves=2)
        rep2 = tr2.run()
        assert rep2.waves == 4      # 2 workers x 2 waves from the restart
