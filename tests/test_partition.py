"""Partitioner: DP exactness vs brute force, memory feasibility, heterogeneity."""
import itertools

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.partition import (DeviceProfile, PAPER_GPUS, layer_costs,
                                  partition_minmax, inflight,
                                  max_concurrent_minibatches)


def brute_force(flops, act, par, devices, nm):
    L, k = len(flops), len(devices)
    best, best_bounds = np.inf, None
    pre_f = np.concatenate([[0.0], np.cumsum(flops)])
    pre_p = np.concatenate([[0.0], np.cumsum(par)])

    def stage_time(a, b, s):
        d = devices[s]
        t = (pre_f[b] - pre_f[a]) / d.eff_flops
        if b < L:
            t += act[b - 1] / (d.link_gbps * 1e9)
        return t

    def stage_mem(a, b, s):
        return (pre_p[b] - pre_p[a]) * 4.0 + float(np.sum(act[a:b])) * \
            inflight(s, k, nm)

    for cuts in itertools.combinations(range(1, L), k - 1):
        bounds = [0, *cuts, L]
        ok = all(stage_mem(bounds[i], bounds[i + 1], i)
                 <= devices[i].mem_gb * 1e9 for i in range(k))
        if not ok:
            continue
        t = max(stage_time(bounds[i], bounds[i + 1], i) for i in range(k))
        if t < best:
            best, best_bounds = t, bounds
    return best, best_bounds


# seeded stand-in for the original hypothesis property test: 60 random cases
_DP_CASES = [(int(r.integers(4, 10)), int(r.integers(2, 5)),
              int(r.integers(0, 10_000)))
             for r in [np.random.default_rng(7)] for _ in range(60)]


@pytest.mark.parametrize("L,k,seed", _DP_CASES)
def test_dp_matches_brute_force(L, k, seed):
    if k > L:
        return
    rng = np.random.default_rng(seed)
    flops = rng.uniform(1e9, 1e12, L)
    act = rng.uniform(1e5, 1e7, L)
    par = rng.uniform(1e6, 1e8, L)
    devices = [DeviceProfile(f"d{i}", rng.uniform(5, 200), rng.uniform(4, 24))
               for i in range(k)]
    bf_t, bf_bounds = brute_force(flops, act, par, devices, nm=2)
    bounds, times, ok = partition_minmax(flops, act, par, devices, nm=2)
    if bf_bounds is None:
        assert not ok
    else:
        assert ok
        assert np.isclose(max(times), bf_t, rtol=1e-9)


def test_memory_constraints_respected():
    cfg = ARCHS["qwen3-0.6b"]
    fl, pb, ab = layer_costs(cfg, 4096, 4 * 4096)
    devs = [PAPER_GPUS[c] for c in "VRGQ"]
    bounds, _, ok = partition_minmax(fl, ab, pb, devs, nm=4)
    assert ok
    k = len(devs)
    for s in range(k):
        a, b = bounds[s], bounds[s + 1]
        mem = np.sum(pb[a:b]) * 4.0 + np.sum(ab[a:b]) * inflight(s, k, 4)
        assert mem <= devs[s].mem_gb * 1e9


def test_hetero_gives_fast_devices_more_layers():
    """A much faster device must not get fewer layers than a slow one when
    communication is negligible."""
    L = 16
    flops = np.full(L, 1e12)
    act = np.full(L, 1.0)          # negligible comm
    par = np.full(L, 1e6)
    fast = DeviceProfile("fast", 100.0, 64.0)
    slow = DeviceProfile("slow", 10.0, 64.0)
    bounds, times, ok = partition_minmax(flops, act, par, [fast, slow], nm=2)
    assert ok
    n_fast = bounds[1] - bounds[0]
    n_slow = bounds[2] - bounds[1]
    assert n_fast > n_slow


def test_position_dependent_memory_model():
    """Stage 0 must hold more in-flight activations than the last stage
    (paper Section 4, Figure 1)."""
    assert inflight(0, 4, 8) > inflight(3, 4, 8)
    assert inflight(3, 4, 8) == 1


def test_max_m_shrinks_with_memory():
    cfg = ARCHS["qwen3-0.6b"]
    big = [DeviceProfile("big", 100, 24.0)] * 4
    tiny = [DeviceProfile("tiny", 100, 0.05)] * 4
    assert max_concurrent_minibatches(cfg, big, 4096, 4 * 4096, nm_cap=8) \
        >= max_concurrent_minibatches(cfg, tiny, 4096, 4 * 4096, nm_cap=8)
