"""Partitioner: DP exactness vs brute force, memory feasibility, heterogeneity."""
import itertools

import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core.partition import (DeviceProfile, PAPER_GPUS, layer_costs,
                                  partition_minmax, inflight,
                                  max_concurrent_minibatches)


def brute_force(flops, act, par, devices, nm):
    L, k = len(flops), len(devices)
    best, best_bounds = np.inf, None
    pre_f = np.concatenate([[0.0], np.cumsum(flops)])
    pre_p = np.concatenate([[0.0], np.cumsum(par)])

    def stage_time(a, b, s):
        d = devices[s]
        t = (pre_f[b] - pre_f[a]) / d.eff_flops
        if b < L:
            t += act[b - 1] / (d.link_gbps * 1e9)
        return t

    def stage_mem(a, b, s):
        return (pre_p[b] - pre_p[a]) * 4.0 + float(np.sum(act[a:b])) * \
            inflight(s, k, nm)

    for cuts in itertools.combinations(range(1, L), k - 1):
        bounds = [0, *cuts, L]
        ok = all(stage_mem(bounds[i], bounds[i + 1], i)
                 <= devices[i].mem_gb * 1e9 for i in range(k))
        if not ok:
            continue
        t = max(stage_time(bounds[i], bounds[i + 1], i) for i in range(k))
        if t < best:
            best, best_bounds = t, bounds
    return best, best_bounds


# seeded stand-in for the original hypothesis property test: 60 random cases
_DP_CASES = [(int(r.integers(4, 10)), int(r.integers(2, 5)),
              int(r.integers(0, 10_000)))
             for r in [np.random.default_rng(7)] for _ in range(60)]


@pytest.mark.parametrize("L,k,seed", _DP_CASES)
def test_dp_matches_brute_force(L, k, seed):
    if k > L:
        return
    rng = np.random.default_rng(seed)
    flops = rng.uniform(1e9, 1e12, L)
    act = rng.uniform(1e5, 1e7, L)
    par = rng.uniform(1e6, 1e8, L)
    devices = [DeviceProfile(f"d{i}", rng.uniform(5, 200), rng.uniform(4, 24))
               for i in range(k)]
    bf_t, bf_bounds = brute_force(flops, act, par, devices, nm=2)
    bounds, times, ok = partition_minmax(flops, act, par, devices, nm=2)
    if bf_bounds is None:
        assert not ok
    else:
        assert ok
        assert np.isclose(max(times), bf_t, rtol=1e-9)


def test_memory_constraints_respected():
    cfg = ARCHS["qwen3-0.6b"]
    fl, pb, ab = layer_costs(cfg, 4096, 4 * 4096)
    devs = [PAPER_GPUS[c] for c in "VRGQ"]
    bounds, _, ok = partition_minmax(fl, ab, pb, devs, nm=4)
    assert ok
    k = len(devs)
    for s in range(k):
        a, b = bounds[s], bounds[s + 1]
        mem = np.sum(pb[a:b]) * 4.0 + np.sum(ab[a:b]) * inflight(s, k, 4)
        assert mem <= devs[s].mem_gb * 1e9


def test_hetero_gives_fast_devices_more_layers():
    """A much faster device must not get fewer layers than a slow one when
    communication is negligible."""
    L = 16
    flops = np.full(L, 1e12)
    act = np.full(L, 1.0)          # negligible comm
    par = np.full(L, 1e6)
    fast = DeviceProfile("fast", 100.0, 64.0)
    slow = DeviceProfile("slow", 10.0, 64.0)
    bounds, times, ok = partition_minmax(flops, act, par, [fast, slow], nm=2)
    assert ok
    n_fast = bounds[1] - bounds[0]
    n_slow = bounds[2] - bounds[1]
    assert n_fast > n_slow


def test_position_dependent_memory_model():
    """Stage 0 must hold more in-flight activations than the last stage
    (paper Section 4, Figure 1)."""
    assert inflight(0, 4, 8) > inflight(3, 4, 8)
    assert inflight(3, 4, 8) == 1


def test_link_aware_stage_time_prices_alpha_beta():
    """With real boundary links the DP pays latency + bytes/bandwidth on the
    link joining consecutive stages, not the sender's own link_gbps."""
    from repro.dist.topology import LinkSpec
    L = 8
    flops = np.full(L, 1e12)
    act = np.full(L, 1e6)
    par = np.full(L, 1e6)
    devs = [DeviceProfile("d", 100.0, 64.0, link_gbps=50.0)] * 2
    slow = [LinkSpec("slow", 0.001, 0.5)]          # 1 MB/s + 0.5 s alpha
    _, t_fast, ok1 = partition_minmax(flops, act, par, devs, nm=2)
    _, t_slow, ok2 = partition_minmax(flops, act, par, devs, nm=2,
                                      links=slow)
    assert ok1 and ok2
    # the slow boundary link dominates stage 0's time: alpha alone is 0.5 s
    assert max(t_slow) > max(t_fast) + 0.4


def test_overlap_stage_time_is_max_of_compute_and_comm():
    """overlap=True gates each stage at max(compute, comm); with comm >>
    compute on the only boundary, the minmax time collapses from
    compute+comm to comm, and is never worse than the serial schedule."""
    from repro.dist.topology import LinkSpec
    L, k = 4, 2
    flops = np.full(L, 1e12)
    act = np.full(L, 1e6)
    par = np.full(L, 1e6)
    devs = [DeviceProfile("d", 100.0, 64.0)] * k
    link = [LinkSpec("wan", 0.01, 0.0)]            # 0.1 s per boundary send
    b_s, t_serial, _ = partition_minmax(flops, act, par, devs, nm=2,
                                        links=link)
    b_o, t_over, _ = partition_minmax(flops, act, par, devs, nm=2,
                                      links=link, overlap=True)
    comm = link[0].transfer_time(act[0])
    comp0 = sum(flops[b_o[0]:b_o[1]]) / devs[0].eff_flops
    assert max(t_over) <= max(t_serial)
    assert t_over[0] == pytest.approx(max(comp0, comm))
    assert b_o[0] == 0 and b_o[-1] == L


def test_overlap_aware_dp_moves_cuts():
    """On a comm-heavy boundary the serial DP sheds compute from the sending
    stage to compensate; the overlap DP does not need to — the two must pick
    different cuts and overlap must win."""
    from repro.dist.topology import LinkSpec
    L = 12
    flops = np.full(L, 1e12)
    act = np.full(L, 1e6)
    par = np.full(L, 1e6)
    devs = [DeviceProfile("d", 100.0, 64.0)] * 2
    # comm ~ one layer's compute: serial DP trades a layer, overlap doesn't
    link = [LinkSpec("wan", act[0] / (flops[0] / devs[0].eff_flops) / 1e9,
                     0.0)]
    b_s, t_s, _ = partition_minmax(flops, act, par, devs, nm=2, links=link)
    b_o, t_o, _ = partition_minmax(flops, act, par, devs, nm=2, links=link,
                                   overlap=True)
    assert max(t_o) < max(t_s)
    assert b_s != b_o


def test_pipeline_throughput_comm_times_and_path_links():
    """pipeline_throughput with separate compute/comm vectors, and
    ClusterTopology.path_links as a links source for the DP."""
    from repro.core.partition import pipeline_throughput
    from repro.dist.topology import make_topology
    comp, comm = [1.0, 1.0], [0.5, 0.0]
    serial = pipeline_throughput(comp, 4, comm_times=comm)
    over = pipeline_throughput(comp, 4, comm_times=comm, overlap=True)
    assert serial == pytest.approx(min(1 / 1.5, 4 / (2 * 2.5)))
    assert over == pytest.approx(min(1 / 1.0, 4 / (2 * 2.0)))
    assert over > serial
    topo = make_topology("hetero", 4)
    links = topo.path_links(["vw0", "vw1", "vw2", "vw3"])
    assert [l.name for l in links] == ["nvlink", "eth10", "pcie"]
    L = 6
    bounds, times, ok = partition_minmax(
        np.full(L, 1e12), np.full(L, 1e6), np.full(L, 1e6),
        [DeviceProfile("d", 100.0, 64.0)] * 3, nm=2, links=links[:2])
    assert ok and len(times) == 3


def test_vw_throughputs_overlap_and_links():
    from repro.dist.topology import ETH_1G
    from repro.core.allocation import vw_throughputs
    cfg = ARCHS["qwen3-0.6b"]
    vws = [[PAPER_GPUS["V"]] * 2 + [PAPER_GPUS["Q"]] * 2]
    base = vw_throughputs(cfg, vws, 4096, 4 * 4096, nm=4)
    linked = vw_throughputs(cfg, vws, 4096, 4 * 4096, nm=4, inter=ETH_1G)
    over = vw_throughputs(cfg, vws, 4096, 4 * 4096, nm=4, inter=ETH_1G,
                          overlap=True)
    assert base[0] > 0
    assert linked[0] < base[0]          # 1 GbE boundary costs throughput
    assert over[0] >= linked[0]         # overlap can only help


def test_max_m_shrinks_with_memory():
    cfg = ARCHS["qwen3-0.6b"]
    big = [DeviceProfile("big", 100, 24.0)] * 4
    tiny = [DeviceProfile("tiny", 100, 0.05)] * 4
    assert max_concurrent_minibatches(cfg, big, 4096, 4 * 4096, nm_cap=8) \
        >= max_concurrent_minibatches(cfg, tiny, 4096, 4 * 4096, nm_cap=8)
