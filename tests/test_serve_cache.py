"""The paged cache subsystem (repro.serve.cache): CacheStore accounting,
paged-vs-contiguous parity, variable-length prompts vs unpadded ground
truth, per-request page budgets, fp8 KV through the paged path, the
deadline admission policy, and the Pallas flash-decode kernel over a
gathered-page layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Engine, Plan, ServeSpec
from repro.api.serving import Request, Scheduler
from repro.configs import ARCHS, reduced
from repro.models import lm
from repro.serve import cache as cache_lib
from repro.serve.cache import CacheStore, make_layout

SERVE_ARCHS = ("qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-3b")

_R = np.random.default_rng(23)
_FAMILY_CASES = [(a, int(_R.integers(0, 1_000))) for a in SERVE_ARCHS]


def _cfg(name: str, **over):
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=256,
                num_microbatches=2)
    if ARCHS[name].attn_type == "swa":
        base["window_size"] = 6        # < max_len: exercise the ring wrap
    base.update(over)
    return reduced(ARCHS[name], **base)


def _reqs(cfg, seed, n, plen, budgets=None, lens=None):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        L = plen if lens is None else lens[i]
        out.append(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, L, dtype=np.int32),
            max_new_tokens=0 if budgets is None else budgets[i]))
    return out


# ---------------------------------------------------------------------------
# CacheStore accounting
# ---------------------------------------------------------------------------
def test_layout_geometry_and_validation():
    lo = make_layout(4, 24, page_size=8)
    assert (lo.pages_per_slot, lo.num_pages, lo.trash_page) == (3, 12, 12)
    assert lo.pages_for(1) == 1 and lo.pages_for(8) == 1
    assert lo.pages_for(9) == 2 and lo.pages_for(24) == 3
    # degenerate: one page per slot
    lo = make_layout(4, 24)
    assert (lo.page_size, lo.pages_per_slot, lo.num_pages) == (24, 1, 4)
    with pytest.raises(ValueError, match="outside"):
        make_layout(4, 24, page_size=25)
    with pytest.raises(ValueError, match="worst-case request"):
        make_layout(4, 24, page_size=8, max_pages=2)


def test_store_alloc_free_accounting():
    cfg = _cfg("qwen3-0.6b")
    store = CacheStore(cfg, make_layout(2, 16, page_size=4),
                       dtype=jnp.float32)
    assert store.stats()["pages_total"] == 8
    assert store.can_alloc(16)
    store.alloc(0, 10)                       # 3 pages
    assert store.pages_in_use == 3
    with pytest.raises(ValueError, match="already holds"):
        store.alloc(0, 4)
    store.alloc(1, 16)                       # 4 pages
    assert store.pages_in_use == 7 and not store.can_alloc(8)
    with pytest.raises(RuntimeError, match="exhausted"):
        store.alloc(2, 8)
    tab = np.asarray(store.tree["block_tab"])
    assert (tab[0] >= 0).sum() == 3 and (tab[1] >= 0).sum() == 4
    store.free(0)
    assert store.pages_in_use == 4 and store.can_alloc(16)
    assert np.all(np.asarray(store.tree["block_tab"])[0] == -1)
    store.free(0)                            # idempotent
    assert store.peak_pages == 7
    with pytest.raises(ValueError, match="exceed max_len"):
        store.alloc(0, 17)
    s = store.stats()
    assert s["pages_in_use"] + s["pages_free"] == s["pages_total"]
    assert s["pool_bytes"] == s["page_bytes"] * s["pages_total"]


# ---------------------------------------------------------------------------
# paged vs contiguous parity (page_size < prompt_len)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,seed", _FAMILY_CASES)
def test_paged_scheduler_matches_contiguous(arch, seed):
    """With page_size < prompt_len every slot's KV is split across pages;
    per-request token streams must match the contiguous degenerate bit for
    bit (greedy)."""
    cfg = _cfg(arch)
    rng = np.random.default_rng(seed)
    budgets = [int(rng.integers(1, 7)) for _ in range(6)]
    reqs = _reqs(cfg, seed, 6, 8, budgets=budgets)
    base = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=6, max_batch=2))
    paged = base.replace(serve=ServeSpec(prompt_len=8, gen=6, max_batch=2,
                                         page_size=4))
    out_c = Scheduler(Engine(base)).run([Request(r.rid, r.prompt.copy(),
                                                 r.max_new_tokens)
                                         for r in reqs])
    out_p = Scheduler(Engine(paged)).run(reqs)
    for a, b in zip(out_c.requests, out_p.requests):
        assert a.rid == b.rid and a.tokens == b.tokens
    if cfg.attn_type == "full":
        assert out_p.pages_total == 8  # ceil((8+6)/4) pages/slot x 2 slots
        assert out_p.peak_pages <= out_p.pages_total
        assert out_p.page_utilization() is not None
    else:
        # no full-attention KV group -> no pool to ration: admission must
        # never block on phantom pages
        assert out_p.pages_total == 0 and out_p.admit_blocked == 0
    assert out_p.page_size == 4


@pytest.mark.parametrize("arch,seed", _FAMILY_CASES)
def test_varlen_prompts_match_unpadded_reference(arch, seed):
    """Variable-length admissions (right-padded prompts + per-row lens)
    must reproduce, per request, the tokens of that request served alone
    with an exactly-sized contiguous cache — across all three families
    (KV masking, ring-buffer masking, SSM/RWKV state no-op on pads)."""
    cfg = _cfg(arch)
    P, G = 8, 4
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(2, P + 1)) for _ in range(4)]
    reqs = _reqs(cfg, seed, 4, P, lens=lens)
    plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=P, gen=G, max_batch=2,
                                          page_size=4))
    rep = Scheduler(Engine(plan)).run([Request(r.rid, r.prompt.copy())
                                       for r in reqs])
    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    for r, stats in zip(reqs, rep.requests):
        L = len(r.prompt)
        assert stats.prompt_len == L
        cache = lm.init_cache(cfg, 1, L + G, dtype=jnp.float32)
        hid, cache, _ = lm.forward_ref(cfg, params, jnp.asarray(r.prompt)[None],
                                       mode="prefill", cache=cache)
        tok = int(jnp.argmax(lm.logits_ref(cfg, params, hid[:, -1:])[0, -1]))
        want = [tok]
        for t in range(1, G):
            hid, cache, _ = lm.forward_ref(
                cfg, params, jnp.asarray([[want[-1]]], jnp.int32),
                mode="decode", cache=cache, pos=jnp.int32(L + t - 1))
            want.append(int(jnp.argmax(lm.logits_ref(cfg, params,
                                                     hid)[0, -1])))
        assert stats.tokens == want, (r.rid, L, stats.tokens, want)


def test_prompt_length_validation():
    cfg = _cfg("qwen3-0.6b")
    sch = Scheduler(Engine(Plan(arch=cfg, serve=ServeSpec(prompt_len=8,
                                                          gen=4,
                                                          max_batch=2))))
    with pytest.raises(ValueError, match="frozen in the Plan"):
        sch.run([Request(rid=0, prompt=np.zeros(9, np.int32))])
    with pytest.raises(ValueError, match="frozen in the Plan"):
        sch.run([Request(rid=0, prompt=np.zeros(0, np.int32))])


# ---------------------------------------------------------------------------
# per-request page budgets (no worst-case reservation)
# ---------------------------------------------------------------------------
def test_mixed_budgets_allocate_fewer_pages_than_worst_case():
    """Request.max_new_tokens sizes each slot's pages by its own budget:
    a mixed-budget batch must peak below the uniform worst case."""
    cfg = _cfg("qwen3-0.6b")
    sv = ServeSpec(prompt_len=8, gen=8, max_batch=2, page_size=4)
    uniform = _reqs(cfg, 5, 4, 8)                       # budget = gen = 8
    mixed = _reqs(cfg, 5, 4, 8, budgets=[2, 1, 2, 1])
    rep_u = Scheduler(Engine(Plan(arch=cfg, serve=sv))).run(uniform)
    rep_m = Scheduler(Engine(Plan(arch=cfg, serve=sv))).run(mixed)
    # worst case: ceil((8+8)/4) = 4 pages x 2 slots in flight
    assert rep_u.peak_pages == 8
    # mixed: ceil((8+2)/4) = 3 pages at most per slot
    assert rep_m.peak_pages <= 6 < rep_u.peak_pages


def test_admission_refused_when_pool_exhausted():
    """A free batch slot is not enough: admission waits for pages. With a
    pool sized for one worst-case request, requests serialize (and the
    blocked rounds are counted) but all complete."""
    cfg = _cfg("qwen3-0.6b")
    sv = ServeSpec(prompt_len=8, gen=8, max_batch=2, page_size=4,
                   max_pages=4)
    reqs = _reqs(cfg, 9, 3, 8)                          # 4 pages each
    rep = Scheduler(Engine(Plan(arch=cfg, serve=sv))).run(reqs)
    assert sorted(r.rid for r in rep.requests) == [0, 1, 2]
    assert all(r.new_tokens == sv.gen for r in rep.requests)
    assert rep.admit_blocked > 0
    assert rep.peak_pages <= 4
    # pool-serialized: admissions cannot overlap
    admits = sorted(r.admitted_step for r in rep.requests)
    assert admits[1] > admits[0] and admits[2] > admits[1]


# ---------------------------------------------------------------------------
# fp8 KV through the paged path
# ---------------------------------------------------------------------------
def test_fp8_paged_scheduler_end_to_end():
    """cache_dtype='f8' through the paged Scheduler path: completes, and
    produces the same streams as fp8 over the contiguous degenerate (the
    quantization, not the layout, decides the tokens)."""
    cfg = _cfg("qwen3-0.6b")
    reqs = _reqs(cfg, 11, 4, 8, budgets=[3, 5, 2, 4])
    f8 = dict(prompt_len=8, gen=6, max_batch=2, cache_dtype="f8")
    rep_p = Scheduler(Engine(Plan(arch=cfg, serve=ServeSpec(
        page_size=4, **f8)))).run([Request(r.rid, r.prompt.copy(),
                                           r.max_new_tokens) for r in reqs])
    rep_c = Scheduler(Engine(Plan(arch=cfg, serve=ServeSpec(**f8)))).run(reqs)
    for a, b in zip(rep_p.requests, rep_c.requests):
        assert a.rid == b.rid and a.tokens == b.tokens
    assert rep_p.tokens_out == sum(r.max_new_tokens for r in reqs)


def test_fp8_halves_page_bytes():
    """CacheStore.stats(): fp8 pages are half the bytes of bf16 pages of
    the same geometry."""
    cfg = _cfg("qwen3-0.6b")
    lo = make_layout(2, 16, page_size=4)
    _, bf16 = cache_lib.serve_dtypes("bfloat16", "")
    _, f8 = cache_lib.serve_dtypes("bfloat16", "f8")
    s_bf16 = CacheStore(cfg, lo, dtype=bf16).stats()
    s_f8 = CacheStore(cfg, lo, dtype=f8).stats()
    assert s_f8["page_bytes"] * 2 == s_bf16["page_bytes"] > 0
    assert s_f8["pool_bytes"] * 2 == s_bf16["pool_bytes"]


# ---------------------------------------------------------------------------
# deadline admission policy
# ---------------------------------------------------------------------------
def test_deadline_policy_orders_by_slack():
    """With one decode slot, the deadline policy admits the tightest-slack
    request first; FIFO admits in arrival order."""
    cfg = _cfg("qwen3-0.6b")
    plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=4, max_batch=1))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 256, 8, dtype=np.int32) for _ in range(3)]
    mk = lambda: [Request(rid=0, prompt=prompts[0], max_new_tokens=2),
                  Request(rid=1, prompt=prompts[1], max_new_tokens=2,
                          deadline=100),
                  Request(rid=2, prompt=prompts[2], max_new_tokens=2,
                          deadline=3)]
    fifo = Scheduler(Engine(plan), policy="fifo").run(mk())
    edf = Scheduler(Engine(plan), policy="deadline").run(mk())
    order_f = [r.rid for r in sorted(fifo.requests,
                                     key=lambda r: r.admitted_step)]
    order_e = [r.rid for r in sorted(edf.requests,
                                     key=lambda r: r.admitted_step)]
    assert order_f == [0, 1, 2]
    # rid 2 (slack 3-2=1) < rid 1 (slack 98) < rid 0 (no deadline, inf)
    assert order_e == [2, 1, 0]
    # a request's tokens never depend on admission order
    for a in fifo.requests:
        b = next(r for r in edf.requests if r.rid == a.rid)
        assert a.tokens == b.tokens


def test_deadline_policy_fifo_among_slack_ties():
    """Equal slack (including all-no-deadline) must keep strict arrival
    order — the no-starvation invariant."""
    cfg = _cfg("qwen3-0.6b")
    plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=4, max_batch=1))
    rng = np.random.default_rng(4)
    no_dl = [Request(rid=i, prompt=rng.integers(0, 256, 8, dtype=np.int32),
                     max_new_tokens=2) for i in range(4)]
    rep = Scheduler(Engine(plan), policy="deadline").run(no_dl)
    order = [r.rid for r in sorted(rep.requests,
                                   key=lambda r: r.admitted_step)]
    assert order == [0, 1, 2, 3]
    same_dl = [Request(rid=i, prompt=rng.integers(0, 256, 8, dtype=np.int32),
                       max_new_tokens=2, deadline=50) for i in range(4)]
    rep = Scheduler(Engine(plan), policy="deadline").run(same_dl)
    order = [r.rid for r in sorted(rep.requests,
                                   key=lambda r: r.admitted_step)]
    assert order == [0, 1, 2, 3]


def test_policy_validation():
    cfg = _cfg("qwen3-0.6b")
    plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=4, max_batch=1))
    with pytest.raises(ValueError, match="unknown admission policy"):
        Scheduler(Engine(plan), policy="lifo")
    sch = Scheduler(Engine(plan))
    with pytest.raises(ValueError, match="deadline"):
        sch.run([Request(rid=0, prompt=np.zeros(8, np.int32), deadline=-1)])


# ---------------------------------------------------------------------------
# ServeSpec page knobs
# ---------------------------------------------------------------------------
def test_serve_spec_page_validation():
    cfg = _cfg("qwen3-0.6b")
    with pytest.raises(ValueError, match="must be >= 0"):
        Plan(arch=cfg, serve=ServeSpec(page_size=-1))
    with pytest.raises(ValueError, match="outside"):
        Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=4, page_size=16))
    with pytest.raises(ValueError, match="worst-case request"):
        Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=8, page_size=4,
                                       max_pages=3))


# ---------------------------------------------------------------------------
# Pallas flash-decode over the gathered-page layout (interpret mode)
# ---------------------------------------------------------------------------
def test_flash_decode_gathered_pages_matches_contiguous():
    """Scatter a contiguous KV cache into a paged pool through a permuted
    block table, gather it back per row, and run the Pallas flash-decode
    kernel on the gathered view: bitwise identical (atol=0) to the kernel
    over the original contiguous layout."""
    from repro.kernels.flash_decode import flash_decode
    B, KV, G, S, hd, ps = 2, 2, 2, 32, 16, 8
    H = KV * G
    lo = make_layout(B, S, page_size=ps)
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    length = S - 3

    # pool with a deliberately permuted page assignment
    pages = rng.permutation(lo.num_pages).reshape(B, lo.pages_per_slot)
    tab = jnp.asarray(pages, jnp.int32)
    pool_shape = (1, lo.num_pages + 1, ps, KV, hd)
    pool_k = jnp.zeros(pool_shape, jnp.float32)
    pool_v = jnp.zeros(pool_shape, jnp.float32)
    sel = jnp.ones((B,), bool)
    pool_k = cache_lib.page_write_prompt(pool_k, 0, tab, k, sel)
    pool_v = cache_lib.page_write_prompt(pool_v, 0, tab, v, sel)
    k_view, gpos = cache_lib.page_view(pool_k, 0, tab)
    v_view, _ = cache_lib.page_view(pool_v, 0, tab)
    # the gather must reproduce the contiguous layout exactly
    np.testing.assert_array_equal(np.asarray(k_view), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(gpos),
                                  np.tile(np.arange(S), (B, 1)))

    to_kernel = lambda a: jnp.transpose(a, (0, 2, 1, 3))   # [B, KV, S, hd]
    out_pages = flash_decode(q, to_kernel(k_view), to_kernel(v_view),
                             length, block_k=ps, interpret=True)
    out_contig = flash_decode(q, to_kernel(k), to_kernel(v), length,
                              block_k=ps, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_pages),
                                  np.asarray(out_contig))
    # and the decode_attend jnp reference agrees on the same view
    from repro.models.attention import decode_attend
    ref = decode_attend(q[:, None], k_view, v_view, gpos,
                        jnp.int32(length - 1))
    np.testing.assert_allclose(np.asarray(ref[:, 0]), np.asarray(out_pages),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_paged_pool_direct_matches_gather_view():
    """The fused kernel consumes the CacheStore pool + block table
    *directly* (the block-table walk lives in the BlockSpec index map) and
    matches the materialize-then-decode path it replaces, at mixed per-row
    depths with unmapped tail pages."""
    from repro.kernels.flash_decode import flash_decode, flash_decode_paged
    B, KV, G, S, hd, ps = 3, 2, 2, 32, 16, 8
    H = KV * G
    lo = make_layout(B, S, page_size=ps)
    rng = np.random.default_rng(5)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, H, hd)), jnp.float32)
    # staggered depths: full row, mid-page row, empty row
    lens = jnp.asarray([S, ps + 3, 0], jnp.int32)

    pages = rng.permutation(lo.num_pages).reshape(B, lo.pages_per_slot)
    tab = jnp.asarray(pages, jnp.int32)
    # rows only own the pages their depth needs; the rest are unmapped
    tab = tab.at[1, 2:].set(-1)
    tab = tab.at[2, :].set(-1)
    pool_shape = (1, lo.num_pages + 1, ps, KV, hd)
    pool_k = cache_lib.page_write_prompt(jnp.zeros(pool_shape), 0, tab, k,
                                         jnp.ones((B,), bool))
    pool_v = cache_lib.page_write_prompt(jnp.zeros(pool_shape), 0, tab, v,
                                         jnp.ones((B,), bool))

    out = flash_decode_paged(q, pool_k, pool_v, tab, lens, layer=0,
                             interpret=True)
    # the replaced path: gather a contiguous view, then contiguous kernel
    k_view, _ = cache_lib.page_view(pool_k, 0, tab)
    v_view, _ = cache_lib.page_view(pool_v, 0, tab)
    to_kernel = lambda a: jnp.transpose(a, (0, 2, 1, 3))
    via_view = flash_decode(q, to_kernel(k_view), to_kernel(v_view), lens,
                            block_k=ps, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(via_view),
                               rtol=2e-5, atol=2e-5)
    assert np.all(np.asarray(out[2]) == 0.0)            # empty row: zeros
    # and the jnp paged oracle agrees
    from repro.kernels import ref as kref
    ref = kref.decode_paged_ref(q, pool_k, pool_v, tab, lens, layer=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("arch,seed", _FAMILY_CASES,
                         ids=[a for a, _ in _FAMILY_CASES])
def test_scheduler_kernel_backend_stream_parity(arch, seed):
    """Satellite of the kernel-backend wiring: continuous-batching token
    streams with slots at different depths are bit-identical between the
    Pallas kernels (interpret mode; paged decode reads the pool through
    the block table with per-row lengths) and the jnp "ref" oracle, for
    every serve family."""
    cfg = _cfg(arch)
    rng = np.random.default_rng(seed)
    lens = [int(rng.integers(2, 9)) for _ in range(6)]
    budgets = [int(rng.integers(1, 6)) for _ in range(6)]

    def run(kb):
        plan = Plan(arch=cfg, serve=ServeSpec(
            prompt_len=8, gen=6, max_batch=2, page_size=4,
            kernel_backend=kb))
        reqs = _reqs(cfg, seed, 6, 8, budgets=budgets, lens=lens)
        return [r.tokens for r in Scheduler(Engine(plan)).run(reqs).requests]

    assert run("ref") == run("interpret")


def test_scheduler_kernel_backend_fp8_stream_parity():
    """fp8 KV pages quantize identically under both backends: the paged
    kernel reads the pool pages as stored and casts in-register."""
    cfg = _cfg("qwen3-0.6b")

    def run(kb):
        plan = Plan(arch=cfg, serve=ServeSpec(
            prompt_len=8, gen=6, max_batch=2, page_size=4, cache_dtype="f8",
            kernel_backend=kb))
        reqs = _reqs(cfg, 11, 6, 8, budgets=[3] * 6,
                     lens=[3, 8, 5, 2, 7, 4])
        return [r.tokens for r in Scheduler(Engine(plan)).run(reqs).requests]

    assert run("ref") == run("interpret")


def test_page_write_token_routes_unmapped_to_trash():
    """Decode writes for unmapped rows land in the trash page, never in a
    live page; mapped rows land at (page, offset) of their position."""
    lo = make_layout(2, 8, page_size=4)
    pool = jnp.zeros((1, lo.num_pages + 1, 4, 1, 2), jnp.float32)
    tab = jnp.asarray([[0, 2], [-1, -1]], jnp.int32)
    row = jnp.ones((2, 1, 1, 2), jnp.float32)
    pos = jnp.asarray([5, 6], jnp.int32)
    out = cache_lib.page_write_token(pool, 0, tab, pos,
                                     row, jnp.asarray([True, True]))
    out = np.asarray(out)
    assert np.all(out[0, 2, 1] == 1.0)          # row 0: page 2, offset 1
    assert np.all(out[0, :lo.num_pages].sum() == 2.0)  # nothing else live
    assert np.all(out[0, lo.trash_page, 2] == 1.0)     # row 1 -> trash
