"""The roofline's cost analyzer is measurement infrastructure — test it.

XLA's cost_analysis counts while bodies once; the jaxpr walker must multiply
scan lengths, count dot FLOPs exactly, and account collectives with ring
factors.
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.jaxpr_analysis import analyze_fn, analyze_jaxpr


class _FakeMesh:
    shape = {"x": 4}


def _analyze(fn, *args, mesh_shape=None):
    jaxpr = jax.make_jaxpr(fn)(*args)
    return analyze_jaxpr(jaxpr.jaxpr, mesh_shape or {}, total_devices=1)


def test_scan_multiplies_trip_count():
    def f(x):
        return jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=8)[0]
    c = _analyze(f, jnp.ones((64, 64)))
    assert np.isclose(c.dot_flops, 8 * 2 * 64 ** 3)


def test_nested_scans_multiply():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, None, length=5)[0]
    c = _analyze(f, jnp.ones((32, 32)))
    assert np.isclose(c.dot_flops, 5 * 3 * 2 * 32 ** 3)


def test_dot_general_flops_batched():
    def f(a, b):
        return jnp.einsum("bik,bkj->bij", a, b)
    c = _analyze(f, jnp.ones((4, 8, 16)), jnp.ones((4, 16, 32)))
    assert np.isclose(c.dot_flops, 2 * 4 * 8 * 16 * 32)


def test_cond_expected_value():
    def f(x, p):
        return jax.lax.cond(p, lambda y: y @ y, lambda y: y, x)
    c = _analyze(f, jnp.ones((32, 32)), jnp.bool_(True))
    # mean over branches: 0.5 * matmul
    assert np.isclose(c.dot_flops, 0.5 * 2 * 32 ** 3)


def test_psum_ring_factor():
    from repro.launch.mesh import make_mesh_auto
    mesh = make_mesh_auto((1,), ("x",))

    def f(x):
        from repro.compat import shard_map
        return shard_map(lambda a: jax.lax.psum(a, "x"), mesh=mesh,
                             in_specs=jax.sharding.PartitionSpec(None),
                             out_specs=jax.sharding.PartitionSpec(None),
                         check_vma=False)(x)
    jaxpr = jax.make_jaxpr(f)(jnp.ones((128,), jnp.float32))
    c = analyze_jaxpr(jaxpr.jaxpr, {"x": 4}, total_devices=4)
    # ring all-reduce: 2*(n-1)/n * payload = 1.5 * 512B
    assert np.isclose(c.collective_bytes["psum"], 1.5 * 512)


def test_dus_counts_update_not_operand():
    def f(big, small):
        return jax.lax.dynamic_update_slice(big, small, (0, 0))
    c = _analyze(f, jnp.ones((1024, 1024)), jnp.ones((2, 2)))
    assert c.bytes_upper <= 2 * 2 * 2 * 4 + 1  # ~2x the 2x2 update
