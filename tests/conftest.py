"""Make the repo root importable (benchmarks/ package) regardless of cwd."""
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
