"""Per-architecture smoke tests (reduced configs) + serve-path consistency.

Every assigned architecture: one forward/train step on CPU asserting output
shapes and no NaNs, plus prefill+decode == full forward (the KV/SSM cache
correctness oracle)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced
from repro.models import lm

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, B, S, key):
    if cfg.frontend != "none":
        return 0.02 * jax.random.normal(key, (B, S, cfg.d_model))
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size, dtype=jnp.int32)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_train_step(name):
    cfg = reduced(ARCHS[name])
    params, _ = lm.init_params(cfg, KEY)
    B, S = 2, 32
    x = _inputs(cfg, B, S, KEY)
    labels = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size,
                                dtype=jnp.int32)

    def loss_fn(p):
        loss, _, _ = lm.forward_ref(cfg, p, x, mode="train", labels=labels)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert jnp.isfinite(loss), name
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0 and jnp.isfinite(gnorm), name
    # one SGD step reduces loss on the same batch
    p2 = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
    loss2, _, _ = lm.forward_ref(cfg, p2, x, mode="train", labels=labels)
    assert float(loss2) < float(loss), name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_hidden_shape_and_finite(name):
    cfg = reduced(ARCHS[name])
    params, _ = lm.init_params(cfg, KEY)
    B, S = 2, 32
    x = _inputs(cfg, B, S, KEY)
    hid, _, _ = lm.forward_ref(cfg, params, x, mode="train")
    assert hid.shape == (B, S, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hid)))
    logits = lm.logits_ref(cfg, params, hid)
    assert logits.shape == (B, S, cfg.vocab_size)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_matches_full_forward(name):
    over = {"capacity_factor": 8.0} if ARCHS[name].num_experts else {}
    cfg = reduced(ARCHS[name], **over)
    params, _ = lm.init_params(cfg, KEY)
    B, S, PRE = 2, 16, 12
    x = _inputs(cfg, B, S, KEY)
    hid, _, _ = lm.forward_ref(cfg, params, x, mode="train")
    full = lm.logits_ref(cfg, params, hid)
    cache = lm.init_cache(cfg, B, S, dtype=jnp.float32)
    hp, cache, _ = lm.forward_ref(cfg, params, x[:, :PRE], mode="prefill",
                                  cache=cache)
    pf = lm.logits_ref(cfg, params, hp)
    assert jnp.allclose(pf, full[:, :PRE], atol=2e-4), name
    for t in range(PRE, S):
        tok = x[:, t:t + 1]
        hd, cache, _ = lm.forward_ref(cfg, params, tok, mode="decode",
                                      cache=cache, pos=jnp.int32(t))
        dl = lm.logits_ref(cfg, params, hd)
        assert jnp.allclose(dl[:, 0], full[:, t], atol=2e-4), (name, t)


def test_param_count_sane():
    """Full configs' analytic param counts are in the advertised ballpark."""
    expect = {
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "minitron-8b": (7e9, 10e9),
        "chameleon-34b": (30e9, 38e9),
        "rwkv6-3b": (2.5e9, 3.6e9),
        "gemma3-1b": (0.8e9, 1.6e9),
        "hymba-1.5b": (1.0e9, 2.0e9),
        "granite-moe-1b-a400m": (0.9e9, 1.6e9),
        "granite-moe-3b-a800m": (2.5e9, 4.0e9),
        "h2o-danube-1.8b": (1.4e9, 2.2e9),
        "musicgen-medium": (1.2e9, 2.2e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, (name, n)


def test_moe_active_params_below_total():
    cfg = ARCHS["granite-moe-1b-a400m"]
    assert cfg.active_param_count() < cfg.param_count()


def test_gemma_local_global_pattern():
    kinds = ARCHS["gemma3-1b"].layer_kinds()[:26]
    assert kinds.count(0) == 4 and kinds[5] == 0 and kinds[0] == 1
