"""repro.faults: seeded fault injection and elastic recovery.

Covers the three injection seams (transport retry/backoff, PS push/gate,
worker fleet eviction + rejoin), the Plan-level validation that anchors a
scenario to its run, the late-push/deregister ordering regression, and the
seeded chaos sweep the ISSUE's acceptance criteria name: bit-identical
fault digests across runs, convergence within tolerance of the fault-free
run, a zero-violation staleness audit, and serve-side slot-fault recovery
with bit-identical token streams.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.api import (BSP, ClusterSpec, DegradedRunError, Engine, FaultPlan,
                       FaultPolicy, GateTimeout, LinkFault, PSStall,
                       PartitionSpec, Plan, PushTimeout, RunSpec, ServeSpec,
                       SlotFault, TransportError, WSP, WorkerCrash,
                       WorkerSlowdown)
from repro.api.serving import Request, Scheduler
from repro.configs import ARCHS, reduced
from repro.core.param_server import ParameterServer
from repro.core.wsp import WSPClockServer
from repro.dist.topology import make_topology
from repro.dist.transport import NullTransport, SimulatedTransport
from repro.faults import FaultInjector
from repro.obs import Tracer

CFG = reduced(ARCHS["qwen3-0.6b"], num_layers=2, d_model=32, d_ff=64,
              vocab_size=256, num_heads=2, num_kv_heads=2, head_dim=16,
              num_microbatches=2)

CHAOS_SEEDS = (3, 5, 11)


# ---------------------------------------------------------------------------
# plan / policy validation
# ---------------------------------------------------------------------------
def test_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultPlan(events=(LinkFault(src="a", dst="b", kind="melt"),))
    with pytest.raises(ValueError, match="window"):
        LinkFault(src="a", dst="b", n_msgs=0).validate()
    with pytest.raises(ValueError, match="probability"):
        LinkFault(src="a", dst="b", kind="loss", p=1.5).validate()
    with pytest.raises(ValueError, match="non-negative"):
        WorkerCrash(vw=-1, wave=0).validate()
    with pytest.raises(ValueError, match="non-negative"):
        PSStall(at_push=-1).validate()
    with pytest.raises(TypeError, match="unknown fault event"):
        FaultPlan(events=("not-an-event",))


def test_policy_validation():
    with pytest.raises(ValueError, match="max_retries"):
        FaultPolicy(max_retries=-1)
    with pytest.raises(ValueError, match="msg_timeout_s"):
        FaultPolicy(msg_timeout_s=-0.1)
    with pytest.raises(ValueError, match="slot_recovery"):
        FaultPolicy(slot_recovery="pray")
    with pytest.raises(ValueError, match="rejoin_after_waves"):
        FaultPolicy(rejoin_after_waves=-1)
    assert not FaultPolicy().rejoins
    assert FaultPolicy(rejoin_after_waves=1).rejoins
    assert FaultPolicy(rejoin_delay_s=0.1).rejoins
    assert not FaultPolicy(rejoin_delay_s=0.1, rejoin_max=0).rejoins


def test_plan_validates_fault_scenarios():
    crash = FaultPlan(events=(WorkerCrash(vw=0, wave=1),))
    # serve plans take SlotFault only; train plans reject it
    with pytest.raises(ValueError, match="this Plan serves"):
        Plan(arch=CFG, serve=ServeSpec(prompt_len=8, gen=4, max_batch=2),
             faults=crash)
    with pytest.raises(ValueError, match="SlotFault is a serving fault"):
        Plan(arch=CFG, faults=FaultPlan(events=(SlotFault(slot=0, step=1),)),
             fault_policy=FaultPolicy(evict_lag=1))
    with pytest.raises(ValueError, match="outside the decode batch"):
        Plan(arch=CFG, serve=ServeSpec(prompt_len=8, gen=4, max_batch=2),
             faults=FaultPlan(events=(SlotFault(slot=5, step=1),)))
    # only the threaded PS runtime has injection seams
    with pytest.raises(ValueError, match="BSP"):
        Plan(arch=CFG, sync=BSP(), faults=crash)
    with pytest.raises(ValueError, match="spmd"):
        Plan(arch=CFG, run=RunSpec(backend="spmd"), faults=crash,
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))
    # event indices must land inside the fleet / run
    with pytest.raises(ValueError, match="outside the fleet"):
        Plan(arch=CFG, cluster=ClusterSpec(num_vw=2),
             faults=FaultPlan(events=(WorkerCrash(vw=7, wave=1),)),
             fault_policy=FaultPolicy(evict_lag=1))
    # a crash in a multi-worker fleet without eviction deadlocks survivors
    with pytest.raises(ValueError, match="evict"):
        Plan(arch=CFG, cluster=ClusterSpec(num_vw=2), faults=crash)


# ---------------------------------------------------------------------------
# injector: deterministic per-attempt verdicts on logical indices
# ---------------------------------------------------------------------------
def test_injector_outage_window_in_attempt_units():
    plan = FaultPlan(events=(
        LinkFault(src="a", dst="b", start_msg=1, n_msgs=2, kind="outage"),))
    inj = FaultInjector(plan)
    # msg 0 = attempt 0: clean, single attempt
    assert inj.message_attempts("a", "b", 4) == [(True, 1.0)]
    # msg 1 = attempts 1 (drop), 2 (drop), 3 (ok): retries walk out of the
    # window because it is measured in attempt indices
    att = inj.message_attempts("a", "b", 4)
    assert [ok for ok, _ in att] == [False, False, True]
    # untouched paths never consume counters
    assert inj.message_attempts("x", "y", 4) == [(True, 1.0)]


def test_injector_deterministic_across_instances():
    plan = FaultPlan(seed=9, events=(
        LinkFault(src="a", dst="b", start_msg=0, n_msgs=50, kind="loss",
                  p=0.5),))
    seqs = []
    for _ in range(2):
        inj = FaultInjector(plan)
        seqs.append([inj.message_attempts("a", "b", 6) for _ in range(12)])
    assert seqs[0] == seqs[1]
    # a different seed must reshuffle the loss draws
    inj = FaultInjector(FaultPlan(seed=10, events=plan.events))
    assert [inj.message_attempts("a", "b", 6) for _ in range(12)] != seqs[0]


def test_injector_worker_and_ps_seams():
    plan = FaultPlan(events=(
        WorkerCrash(vw=1, wave=3), WorkerCrash(vw=1, wave=5),
        WorkerSlowdown(vw=2, wave=2, extra_s=0.4),
        PSStall(at_push=4, seconds=0.5),
        SlotFault(slot=1, step=2), SlotFault(slot=0, step=2),
    ))
    inj = FaultInjector(plan, time_scale=0.1)
    assert inj.crash_wave(1) == 3          # earliest crash wins
    assert inj.crash_wave(0) is None
    assert inj.slowdown_extra(2, 1) == 0.0
    assert inj.slowdown_extra(2, 2) == pytest.approx(0.04)   # scaled
    assert inj.slowdown_extra(0, 9) == 0.0
    assert inj.ps_stall_sleep(4) == pytest.approx(0.05)      # scaled
    assert inj.ps_stall_sleep(3) == 0.0
    assert sorted(inj.slot_faults(2)) == [0, 1]
    assert inj.slot_faults(3) == []
    assert not inj.empty
    assert FaultInjector(None).empty


# ---------------------------------------------------------------------------
# transport: retry/backoff, per-link accounting, typed exhaustion
# ---------------------------------------------------------------------------
def test_simulated_transport_retries_and_accounts():
    topo = make_topology("2node", 4)
    inj = FaultInjector(FaultPlan(events=(
        LinkFault(src="vw2", dst="ps", start_msg=0, n_msgs=2),)))
    tr = SimulatedTransport(topo, time_scale=0.0, injector=inj,
                            policy=FaultPolicy(max_retries=3))
    # first message: attempts 0, 1 drop (the outage window), 2 succeeds
    sec = tr.send("vw2", "ps", 1000)
    s = tr.stats()
    assert s["drops_by_link"]["eth10"] == 2
    assert s["retries_by_link"]["eth10"] == 2
    assert s["drops"] == 2 and s["retries"] == 2
    # failed attempts are charged timeout + capped backoff on the link
    assert s["seconds_by_link"]["eth10"] > 0
    assert sec > 0
    # subsequent messages are clean and charged only the link cost
    before = s["seconds_by_link"]["eth10"]
    tr.send("vw2", "ps", 1000)
    s2 = tr.stats()
    assert s2["drops"] == 2                       # unchanged
    assert s2["seconds_by_link"]["eth10"] > before


def test_simulated_transport_exhaustion_raises_typed():
    topo = make_topology("2node", 4)
    inj = FaultInjector(FaultPlan(events=(
        LinkFault(src="vw2", dst="ps", start_msg=0, n_msgs=100),)))
    tr = SimulatedTransport(topo, time_scale=0.0, injector=inj,
                            policy=FaultPolicy(max_retries=2))
    h = tr.send_async("vw2", "ps", 500)
    with pytest.raises(TransportError, match="vw2->ps"):
        h.wait()
    # every waiter sees the same terminal error
    with pytest.raises(TransportError):
        h.wait()
    assert tr.stats()["drops_by_link"]["eth10"] == 3    # 1 + max_retries


def test_null_transport_fault_path():
    inj = FaultInjector(FaultPlan(events=(
        LinkFault(src="a", dst="b", start_msg=0, n_msgs=1),)))
    tr = NullTransport(injector=inj)
    tr.send("a", "b", 10)               # one retry, then lands
    assert tr.stats()["drops_by_link"]["loopback"] == 1
    tr2 = NullTransport(
        injector=FaultInjector(FaultPlan(events=(
            LinkFault(src="a", dst="b", start_msg=0, n_msgs=9),))),
        policy=FaultPolicy(max_retries=0))
    with pytest.raises(TransportError):
        tr2.send("a", "b", 10)


# ---------------------------------------------------------------------------
# WSP clock + PS: typed gate, late-push/deregister ordering (satellite 2)
# ---------------------------------------------------------------------------
def _tiny_ps(**kw):
    params = {"w": np.zeros(8, np.float32)}
    return ParameterServer(params, num_shards=2, **kw)


def test_clock_wait_reason_disambiguates():
    clk = WSPClockServer(D=0)
    clk.register("a")
    clk.register("b")
    clk.complete_wave("a")
    # a at 1, b at 0, D=0: a must wait -> timeout
    assert clk.wait_reason("a", timeout=0.05) == "timeout"
    out = {}
    t = threading.Thread(
        target=lambda: out.setdefault("r", clk.wait_reason("a", timeout=5.0)))
    t.start()
    time.sleep(0.05)
    clk.deregister("a")
    t.join(5.0)
    assert out["r"] == "evicted"
    # advancing past a departed worker works; if_registered refuses
    assert clk.complete_wave_if_registered("a") is None
    assert clk.complete_wave_if_registered("b") == 1


def test_ps_gate_raises_gate_timeout():
    ps = _tiny_ps(D=0)
    ps.register("a")
    ps.register("b")
    ps.push_wave("a", {"w": np.ones(8, np.float32)})
    with pytest.raises(GateTimeout, match="staleness gate"):
        ps.gate("a", timeout=0.05)
    assert ps.gate("b", timeout=0.05) is True


def test_ps_gate_returns_false_for_evicted():
    ps = _tiny_ps(D=0)
    ps.register("a")
    ps.register("b")
    ps.push_wave("a", {"w": np.ones(8, np.float32)})
    out = {}
    t = threading.Thread(
        target=lambda: out.setdefault("r", ps.gate("a", timeout=5.0)))
    t.start()
    time.sleep(0.05)
    ps.deregister("a")
    t.join(5.0)
    assert out["r"] is False


def test_late_push_after_deregister_never_advances_clock():
    """Satellite 2: a crashed worker's in-flight push must apply its delta
    (stale-but-sound) but never advance the global clock past what the
    survivors gated against."""
    ps = _tiny_ps(D=4)
    ps.register("a")
    ps.register("b")
    pending = ps.begin_push("a", {"w": np.ones(8, np.float32)})
    ps.deregister("a")                  # crash lands between wire and apply
    clock = ps.finish_push(pending)     # must not raise, must not advance
    assert clock == -1
    assert ps.late_pushes == 1
    assert ps.clock.global_clock() == 0          # b still at 0
    assert "a" not in ps.clock.state.clocks
    got = np.asarray(jax.tree.leaves(ps.pull())[0])
    assert np.allclose(got, 1.0)                 # the delta itself landed


def test_push_timeout_is_typed():
    inj = FaultInjector(FaultPlan(events=(
        LinkFault(src="a", dst="ps", start_msg=0, n_msgs=50),)))
    ps = _tiny_ps(D=2, transport=NullTransport(
        injector=inj, policy=FaultPolicy(max_retries=1)))
    ps.register("a")
    with pytest.raises(PushTimeout, match="did not land"):
        ps.push_wave("a", {"w": np.ones(8, np.float32)})
    assert ps.push_count == 0 and ps.clock.state.clocks["a"] == 0


# ---------------------------------------------------------------------------
# engine: loud degraded completion (satellite 1)
# ---------------------------------------------------------------------------
def _chaos_plan(seed=None, events=None, *, num_vw=3, waves=6, topology=None,
                **pol):
    faults = FaultPlan(seed=seed or 0, events=events or ())
    defaults = dict(evict_lag=1, rejoin_after_waves=1, stall_grace_s=5.0)
    defaults.update(pol)
    return Plan(arch=CFG,
                cluster=ClusterSpec(num_vw=num_vw, topology=topology,
                                    time_scale=0.001),
                sync=WSP(D=1),
                run=RunSpec(max_waves=waves, batch=4, seq=16),
                faults=faults, fault_policy=FaultPolicy(**defaults))


def test_unrecovered_transport_death_fails_loudly():
    events = (LinkFault(src="vw1", dst="ps", start_msg=0, n_msgs=10_000),)
    plan = _chaos_plan(events=events, num_vw=2, waves=3,
                       rejoin_after_waves=None, max_retries=1)
    with pytest.raises(DegradedRunError) as ei:
        Engine(plan).fit()
    rep = ei.value.report
    assert rep is not None and rep.crashes >= 1
    assert rep.drops >= 2
    # opting into degraded completion returns the same report instead
    plan2 = _chaos_plan(events=events, num_vw=2, waves=3,
                        rejoin_after_waves=None, max_retries=1,
                        allow_degraded=True)
    rep2 = Engine(plan2).fit()
    assert rep2.crashes >= 1
    assert rep2.fault_digest() == rep.fault_digest()
    assert rep2.waves_requested == 6
    assert rep2.waves < rep2.waves_requested     # visibly truncated


# ---------------------------------------------------------------------------
# chaos sweep: determinism, convergence, staleness audit (acceptance)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def fault_free_report():
    plan = Plan(arch=CFG,
                cluster=ClusterSpec(num_vw=3, time_scale=0.001),
                sync=WSP(D=1),
                run=RunSpec(max_waves=6, batch=4, seq=16))
    return Engine(plan).fit()


def _sampled_chaos_plan(seed):
    faults = FaultPlan.sample_train(seed, num_vw=3, max_waves=6)
    return Plan(arch=CFG,
                cluster=ClusterSpec(num_vw=3, time_scale=0.001),
                sync=WSP(D=1),
                run=RunSpec(max_waves=6, batch=4, seq=16),
                faults=faults,
                fault_policy=FaultPolicy(evict_lag=1, rejoin_after_waves=1,
                                         stall_grace_s=5.0))


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_deterministic_and_convergent(seed, fault_free_report):
    eng = Engine(_sampled_chaos_plan(seed), tracer=Tracer(enabled=True))
    rep = eng.fit()
    rep2 = Engine(_sampled_chaos_plan(seed)).fit()
    # (a) the fault digest is bit-identical across runs of the same seed
    assert rep.fault_digest() == rep2.fault_digest()
    # the sampled scenario always crashes one worker: it must have been
    # evicted as 'dead' (no goodbye) and its successor must finish waves
    assert rep.crashes >= 1
    assert any(r == "dead" for _, r in rep.fault_digest()["evictions"])
    assert rep.rejoins
    rejoined = rep.rejoins[0]
    assert eng.workers[rejoined].done
    assert eng.workers[rejoined].metrics.waves > 0
    # (b) final loss within tolerance of the fault-free run
    tail = lambda r: np.mean([l for _, _, l in r.losses][-3:])
    assert abs(tail(rep) - tail(fault_free_report)) \
        / abs(tail(fault_free_report)) < 0.2
    # (c) recovery respected D: the traced run audits zero violations, and
    # the rejoined worker was gated from its very first wave
    tel = rep.telemetry
    assert tel.counters.get("wsp/staleness_violations", 0) == 0
    assert tel.staleness_max() is not None and tel.staleness_max() <= 1


def test_rejoin_traffic_lands_on_failed_nodes_links():
    """Satellite 3: the successor worker is aliased onto the failed
    worker's topology endpoint, so its PS traffic is billed to the failed
    node's links."""
    events = (WorkerCrash(vw=2, wave=1),)
    plan = _chaos_plan(events=events, topology="2node")
    eng = Engine(plan)
    rep = eng.fit()
    assert rep.rejoins == ["vw2r"]
    topo = eng.topology
    assert topo.link("vw2r", "ps").name == topo.link("vw2", "ps").name
    # the rejoiner pushed real bytes, and they were accounted on a known
    # link (resolving through the alias, not dropped on the floor)
    assert eng.workers["vw2r"].metrics.waves > 0
    assert sum(rep.comm.get("bytes_by_link", {}).values()) > 0


# ---------------------------------------------------------------------------
# serving: slot faults, quarantine, requeue/reprefill, shedding
# ---------------------------------------------------------------------------
def _serve_plan(events=(), *, max_batch=2, gen=6, prompt_len=8, **pol):
    kw = {}
    if events:
        kw = dict(faults=FaultPlan(events=tuple(events)),
                  fault_policy=FaultPolicy(**pol))
    return Plan(arch=CFG,
                serve=ServeSpec(prompt_len=prompt_len, gen=gen,
                                max_batch=max_batch),
                **kw)


def _requests(n, prompt_len=8, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab_size, prompt_len,
                                        dtype=np.int32))
            for i in range(n)]


def test_slot_fault_requeue_streams_bit_identical():
    """Acceptance (d): under a slot fault every admitted request still
    emits its fault-free token stream, bit for bit."""
    baseline = Scheduler(Engine(_serve_plan())).run(_requests(4))
    rep = Scheduler(Engine(_serve_plan(
        events=[SlotFault(slot=0, step=2)]))).run(_requests(4))
    assert rep.slot_faults == 1
    assert rep.requeues == 1
    assert rep.quarantined == 1
    assert rep.failed_requests == 0
    want = {r.rid: r.tokens for r in baseline.requests}
    for r in rep.requests:
        assert r.tokens == want[r.rid], f"rid {r.rid} diverged"
    faulted = [r for r in rep.requests if r.retries]
    assert len(faulted) == 1 and faulted[0].retries == 1
    # two runs of the same faulted plan are bit-identical too
    rep2 = Scheduler(Engine(_serve_plan(
        events=[SlotFault(slot=0, step=2)]))).run(_requests(4))
    assert [r.tokens for r in rep2.requests] == \
        [r.tokens for r in rep.requests]


def test_slot_fault_reprefill_keeps_tokens():
    baseline = Scheduler(Engine(_serve_plan())).run(_requests(2,
                                                             prompt_len=4))
    rep = Scheduler(Engine(_serve_plan(
        events=[SlotFault(slot=0, step=2)],
        slot_recovery="reprefill", quarantine_slots=False))).run(
        _requests(2, prompt_len=4))
    assert rep.slot_faults == 1
    assert rep.reprefills == 1 and rep.requeues == 0
    want = {r.rid: r.tokens for r in baseline.requests}
    for r in rep.requests:
        assert r.tokens == want[r.rid]


def test_slot_retry_budget_exhaustion_fails_request():
    rep = Scheduler(Engine(_serve_plan(
        events=[SlotFault(slot=0, step=1), SlotFault(slot=0, step=3)],
        max_batch=1, quarantine_slots=False,
        slot_retry_budget=1))).run(_requests(2))
    assert rep.slot_faults == 2
    assert rep.failed_requests == 1
    failed = [r for r in rep.requests if r.failed]
    assert len(failed) == 1 and failed[0].retries == 2
    # the survivor still completed its full budget
    done = [r for r in rep.requests if not r.failed and not r.shed]
    assert done and all(r.new_tokens == 6 for r in done)


def test_shed_after_faults_refuses_queue():
    rep = Scheduler(Engine(_serve_plan(
        events=[SlotFault(slot=0, step=1)],
        shed_after_faults=1))).run(_requests(6))
    assert rep.slot_faults == 1
    assert rep.shed >= 1
    shed = [r for r in rep.requests if r.shed]
    assert len(shed) == rep.shed
    assert all(not r.tokens for r in shed)
    # every request is accounted exactly once
    assert sorted(r.rid for r in rep.requests) == list(range(6))
