"""Serve-mode Plans through the Engine: Plan validation, generate() parity
with the forward_ref oracle, continuous-batching scheduler invariants, and
the subprocess parity harness on a real pipelined mesh (the three serve
arch families of examples/serve_batched.py: dense GQA, sliding-window,
RWKV6)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (BSP, ClusterSpec, Engine, PartitionSpec, Plan,
                       RunSpec, ServeSpec, WSP, get_preset)
from repro.api.serving import Request, Scheduler
from repro.configs import ARCHS, ShapeConfig, reduced
from repro.models import lm

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SERVE_ARCHS = ("qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-3b")

# seeded prompt/request cases, test_wsp.py-style
_R = np.random.default_rng(31)
_PARITY_CASES = [(a, int(_R.integers(0, 1_000))) for a in SERVE_ARCHS]
_SCHED_CASES = [(int(_R.integers(0, 1_000)), int(_R.integers(2, 4)),
                 int(_R.integers(3, 8))) for _ in range(4)]


def _cfg(name: str, **over):
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=256,
                num_microbatches=2)
    if ARCHS[name].attn_type == "swa":
        base["window_size"] = 6        # < max_len: exercise the ring wrap
    base.update(over)
    return reduced(ARCHS[name], **base)


def _prompts(cfg, seed, b, p):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, (b, p)), jnp.int32)


# ---------------------------------------------------------------------------
# Plan validation: serve knobs on train Plans and vice versa
# ---------------------------------------------------------------------------
def test_serve_plan_validation():
    cfg = _cfg("qwen3-0.6b")
    sv = ServeSpec(prompt_len=8, gen=4, max_batch=2)
    with pytest.raises(ValueError, match="arch is required"):
        Plan(serve=sv)
    with pytest.raises(ValueError, match="all must be >= 1"):
        Plan(arch=cfg, serve=ServeSpec(gen=0))
    with pytest.raises(ValueError, match="temperature"):
        Plan(arch=cfg, serve=ServeSpec(temperature=-0.5))
    with pytest.raises(ValueError, match="cache_dtype"):
        Plan(arch=cfg, serve=ServeSpec(cache_dtype="fp4"))
    # serve shapes are frozen in the ServeSpec, not Plan.shape
    with pytest.raises(ValueError, match="drop Plan.shape"):
        Plan(arch=cfg, serve=sv, shape=ShapeConfig("x", 8, 2, "prefill"))
    # serving runs no gradient sync
    with pytest.raises(ValueError, match="no gradient synchronization"):
        Plan(arch=cfg, serve=sv, sync=BSP())
    with pytest.raises(ValueError, match="no gradient synchronization"):
        Plan(arch=cfg, serve=sv, sync=WSP(D=2))
    # train-only knobs the serve path would silently drop
    with pytest.raises(ValueError, match="no optimizer state"):
        Plan(arch=cfg, serve=sv, run=RunSpec(ckpt_dir="/tmp/x"))
    with pytest.raises(ValueError, match="moves KV cache"):
        Plan(arch=cfg, serve=sv, run=RunSpec(codec="topk:0.25"))
    with pytest.raises(ValueError, match="batches requests"):
        Plan(arch=cfg, serve=sv, cluster=ClusterSpec(num_vw=2))
    # cluster.topology alone is legal on serve Plans now: it prices the
    # Router's dispatch (see repro.serve.router)
    Plan(arch=cfg, serve=sv, cluster=ClusterSpec(topology="2node"))
    with pytest.raises(ValueError, match="unknown topology"):
        Plan(arch=cfg, serve=sv, cluster=ClusterSpec(topology="bogus"))
    # spmd serve keeps the whole batch on the model mesh
    with pytest.raises(ValueError, match="data-parallel serve"):
        Plan(arch=cfg, serve=sv, run=RunSpec(backend="spmd"),
             partition=PartitionSpec(stages=2, tp=1, data=2, devices=4))
    # and the reverse: serving shapes on a train Plan stay rejected
    with pytest.raises(ValueError, match="serving\\s+shape"):
        Plan(arch=cfg, shape=ShapeConfig("x", 64, 8, "decode"),
             run=RunSpec(backend="spmd", batch=8, seq=64),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))


def test_engine_surface_refuses_mismatched_plans():
    cfg = _cfg("qwen3-0.6b")
    serve_plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=4,
                                                max_batch=2))
    train_plan = Plan(arch=cfg, run=RunSpec(max_waves=1, batch=4, seq=16))
    with pytest.raises(ValueError, match="generate"):
        Engine(serve_plan).fit()
    with pytest.raises(ValueError, match="prefill"):
        Engine(serve_plan).step()
    eng = Engine(train_plan)
    with pytest.raises(ValueError, match="Plan.serve is unset"):
        eng.generate()
    with pytest.raises(ValueError, match="Plan.serve is unset"):
        eng.prefill(np.zeros((2, 8), np.int32))
    with pytest.raises(ValueError, match="Plan.serve is unset"):
        eng.decode(np.zeros((2, 1), np.int32), None, 0)
    with pytest.raises(ValueError, match="Plan.serve is unset"):
        Scheduler(eng)


def test_prefill_rejects_wrong_shapes():
    cfg = _cfg("qwen3-0.6b")
    eng = Engine(Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=4,
                                                max_batch=2)))
    with pytest.raises(ValueError, match="frozen serve shapes"):
        eng.prefill(np.zeros((2, 9), np.int32))
    with pytest.raises(ValueError, match="frozen serve shapes"):
        eng.prefill(np.zeros((3, 8), np.int32))


# ---------------------------------------------------------------------------
# generate() parity with the forward_ref oracle (greedy, bit-identical)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,seed", _PARITY_CASES)
def test_generate_matches_forward_ref_greedy(arch, seed):
    """Engine.generate() on the threads backend must reproduce a hand-rolled
    forward_ref prefill + greedy decode loop token for token."""
    cfg = _cfg(arch)
    sv = ServeSpec(prompt_len=8, gen=5, max_batch=2)
    prompts = _prompts(cfg, seed, sv.max_batch, sv.prompt_len)
    rep = Engine(Plan(arch=cfg, serve=sv)).generate(prompts)

    params, _ = lm.init_params(cfg, jax.random.PRNGKey(0))
    cache = lm.init_cache(cfg, sv.max_batch, sv.max_len, dtype=jnp.float32)
    hid, cache, _ = lm.forward_ref(cfg, params, prompts, mode="prefill",
                                   cache=cache)
    tok = jnp.argmax(lm.logits_ref(cfg, params, hid[:, -1:])[:, -1], axis=-1)
    toks = [tok]
    for t in range(1, sv.gen):
        hid, cache, _ = lm.forward_ref(cfg, params, toks[-1][:, None],
                                       mode="decode", cache=cache,
                                       pos=jnp.int32(sv.prompt_len + t - 1))
        toks.append(jnp.argmax(lm.logits_ref(cfg, params, hid)[:, -1],
                               axis=-1))
    ref = np.stack([np.asarray(t) for t in toks], axis=1)
    np.testing.assert_array_equal(rep.tokens, ref)


@pytest.mark.parametrize("arch,seed", _PARITY_CASES)
def test_generate_spmd_matches_ref_backend(arch, seed):
    """The pipelined serve steps on a 1x1x1 mesh (single CPU device) must
    produce bit-identical greedy tokens to the forward_ref backend — same
    Plan, only run.backend differs (the deeper 2-stage/2-tp mesh parity
    runs in the subprocess harness below)."""
    cfg = _cfg(arch, stages=1, tp=1)
    sv = ServeSpec(prompt_len=8, gen=4, max_batch=2)
    prompts = _prompts(cfg, seed, sv.max_batch, sv.prompt_len)
    rep_ref = Engine(Plan(arch=cfg, serve=sv)).generate(prompts)
    rep_spmd = Engine(Plan(arch=cfg, serve=sv,
                           partition=PartitionSpec(stages=1, tp=1, data=1),
                           run=RunSpec(backend="spmd"))).generate(prompts)
    np.testing.assert_array_equal(rep_spmd.tokens, rep_ref.tokens)
    assert rep_spmd.backend == "spmd" and rep_ref.backend == "threads"


def test_generate_sampled_is_seeded():
    """temperature > 0 samples; the stream is deterministic in sample_seed
    and in range."""
    cfg = _cfg("qwen3-0.6b")
    sv = ServeSpec(prompt_len=8, gen=4, max_batch=2, temperature=1.0,
                   sample_seed=7)
    a = Engine(Plan(arch=cfg, serve=sv)).generate()
    b = Engine(Plan(arch=cfg, serve=sv)).generate()
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.tokens.min() >= 0 and a.tokens.max() < cfg.vocab_size
    c = Engine(Plan(arch=cfg, serve=ServeSpec(
        prompt_len=8, gen=4, max_batch=2, temperature=1.0,
        sample_seed=8))).generate()
    assert not np.array_equal(a.tokens, c.tokens)


def test_generate_frontend_arch_routes_embeddings():
    """Stub-frontend archs serve through synthesized frame embeddings (the
    old launch/serve.py fed raw token ids into the decode path)."""
    cfg = _cfg("musicgen-medium")
    assert cfg.frontend != "none"
    sv = ServeSpec(prompt_len=8, gen=3, max_batch=2)
    rep = Engine(Plan(arch=cfg, serve=sv)).generate()
    assert rep.tokens.shape == (2, 3)
    assert rep.tokens.min() >= 0 and rep.tokens.max() < cfg.vocab_size
    # the scheduler feeds ids back, which stub frontends cannot embed
    with pytest.raises(ValueError, match="stub-frontend"):
        Scheduler(Engine(Plan(arch=cfg, serve=sv)))


# ---------------------------------------------------------------------------
# continuous-batching scheduler invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed,max_batch,n_req", _SCHED_CASES)
def test_scheduler_invariants(seed, max_batch, n_req):
    """FIFO admission (no request starves), retired slots are reused, and
    ServeReport token counts reconcile with the requests admitted."""
    cfg = _cfg("qwen3-0.6b")
    gen = 6
    rng = np.random.default_rng(seed)
    plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=gen,
                                          max_batch=max_batch))
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8,
                                        dtype=np.int32),
                    max_new_tokens=int(rng.integers(1, gen + 1)))
            for i in range(n_req)]
    rep = Scheduler(Engine(plan)).run(list(reqs))
    # every request completed with exactly its budget
    assert sorted(r.rid for r in rep.requests) == list(range(n_req))
    for r, stats in zip(reqs, rep.requests):
        assert stats.new_tokens == (r.max_new_tokens or gen)
        assert 0 <= stats.slot < max_batch
        assert stats.finished_step >= stats.admitted_step
    # token counts reconcile
    assert rep.tokens_out == sum(r.max_new_tokens or gen for r in reqs)
    assert rep.slot_steps <= rep.decode_steps * max_batch
    # FIFO: admission order follows request order (no starvation)
    admits = [s.admitted_step for s in rep.requests]
    assert admits == sorted(admits)
    # slot reuse: more requests than slots forces a retired slot back in
    if n_req > max_batch:
        slots = [s.slot for s in rep.requests]
        assert len(set(slots)) < len(slots)
    occ = rep.occupancy()
    assert occ is not None and 0 < occ <= 1


def test_scheduler_co_batched_outputs_independent():
    """A request's tokens must not depend on its co-batched neighbors:
    batch-of-1 (max_batch=1 Plan) and batched (max_batch=3) runs produce
    bit-identical per-request streams, and the same holds within one
    compiled shape when neighbors differ."""
    cfg = _cfg("qwen3-0.6b")
    rng = np.random.default_rng(101)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8,
                                        dtype=np.int32))
            for i in range(3)]
    big = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=5, max_batch=3))
    one = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=5, max_batch=1))
    batched = Scheduler(Engine(big)).run(list(reqs))
    for r, stats in zip(reqs, batched.requests):
        alone = Scheduler(Engine(one)).run([r])
        assert alone.requests[0].tokens == stats.tokens
    # same compiled shape, different neighbors: rid 0 alone in the batch
    solo = Scheduler(Engine(big)).run([reqs[0]])
    assert solo.requests[0].tokens == batched.requests[0].tokens


def test_decode_row_logits_independent_of_neighbors():
    """Engine.decode row values are bitwise independent of other rows (the
    property the scheduler's slot isolation rests on)."""
    cfg = _cfg("qwen3-0.6b")
    sv = ServeSpec(prompt_len=8, gen=4, max_batch=2)
    eng = Engine(Plan(arch=cfg, serve=sv))
    prompts = _prompts(cfg, 55, 2, 8)
    _, cache = eng.prefill(prompts)
    toks = np.array([[3], [200]], np.int32)
    pos = np.array([8, 8], np.int32)
    lg_a, _ = eng.decode(toks, cache, pos)
    # perturb row 1's token and position; row 0 must not move a bit
    toks_b = np.array([[3], [77]], np.int32)
    pos_b = np.array([8, 9], np.int32)
    lg_b, _ = eng.decode(toks_b, cache, pos_b)
    np.testing.assert_array_equal(np.asarray(lg_a)[0], np.asarray(lg_b)[0])
    assert not np.array_equal(np.asarray(lg_a)[1], np.asarray(lg_b)[1])


def test_scheduler_rejects_oversized_requests():
    cfg = _cfg("qwen3-0.6b")
    plan = Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=4, max_batch=2))
    sch = Scheduler(Engine(plan))
    with pytest.raises(ValueError, match="frozen in the Plan"):
        sch.run([Request(rid=0, prompt=np.zeros(9, np.int32))])
    with pytest.raises(ValueError, match="must be in"):
        sch.run([Request(rid=0, prompt=np.zeros(8, np.int32),
                         max_new_tokens=5)])
    with pytest.raises(ValueError, match="must be in"):
        sch.run([Request(rid=0, prompt=np.zeros(8, np.int32),
                         max_new_tokens=-2)])


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------
def test_serve_presets_build_and_run():
    plan = get_preset("serve_tiny", serve__gen=3)
    assert plan.serve is not None and plan.serve.gen == 3
    rep = Engine(plan).generate()
    assert rep.tokens.shape == (plan.serve.max_batch, 3)
    spmd = get_preset("serve_spmd")
    assert spmd.run.backend == "spmd" and spmd.serve is not None


# ---------------------------------------------------------------------------
# subprocess: parity on a real (1, 2, 2) pipelined mesh
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,seed", _PARITY_CASES)
def test_serve_parity_on_pipelined_mesh(arch, seed):
    """build_prefill_step/build_decode_step (and Engine.generate / the
    Scheduler on top of them) must match the forward_ref cache path on a
    2-stage, 2-tp mesh — logits to tolerance, greedy tokens bit-identical."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "serve_parity_main.py"),
         arch, str(seed)],
        capture_output=True, text=True, timeout=1200, env=env)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    assert "generate_tokens_identical=1" in r.stdout
    assert "scheduler_tokens_identical=1" in r.stdout
    assert "paged_scheduler_tokens_identical=1" in r.stdout
    assert "shared_prefix_tokens_identical=1" in r.stdout
    assert "kernel_backend_tokens_identical=1" in r.stdout
