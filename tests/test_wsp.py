"""Property tests for the WSP clock machine (paper Section 5)."""
import threading

import numpy as np
import pytest

from repro.core.wsp import WSPClockState, WSPClockServer, StalenessViolation

# seeded stand-ins for the original hypothesis property tests
_R = np.random.default_rng(11)
_SCHED_CASES = [(int(_R.integers(2, 7)), int(_R.integers(0, 5)),
                 [int(x) for x in _R.integers(0, 6, int(_R.integers(1, 201)))])
                for _ in range(200)]
_ND_CASES = sorted({(int(_R.integers(2, 6)), int(_R.integers(0, 4)))
                    for _ in range(50)})


@pytest.mark.parametrize("n,D,schedule", _SCHED_CASES)
def test_staleness_bound_never_violated(n, D, schedule):
    """Under any admissible schedule, the clock distance stays <= D + 1 and
    the gating rule matches the paper: a VW at clock c may proceed iff
    c - D <= c_global."""
    s = WSPClockState(D)
    for i in range(n):
        s.add_worker(f"w{i}")
    for pick in schedule:
        wid = f"w{pick % n}"
        if s.can_proceed(wid):
            s.complete_wave(wid)
            # invariant: max distance bounded by D + 1 (a worker may finish
            # the wave it was allowed to start)
            assert s.max_distance() <= D + 1
        else:
            # blocked worker is exactly D + ... ahead of global
            assert s.clocks[wid] - s.global_clock() > D
            with pytest.raises(StalenessViolation):
                s.complete_wave(wid)
            s.clocks[wid] -= 1  # undo the raise's increment guard
            s.clocks[wid] += 1


@pytest.mark.parametrize("n,D", _ND_CASES)
def test_fastest_worker_gets_blocked(n, D):
    """A worker running alone can complete exactly D+1 waves, then blocks."""
    s = WSPClockState(D)
    for i in range(n):
        s.add_worker(f"w{i}")
    done = 0
    while s.can_proceed("w0") and done < D + 5:
        s.complete_wave("w0")
        done += 1
    assert done == D + 1


@pytest.mark.parametrize("n,D", _ND_CASES)
def test_elastic_remove_unblocks(n, D):
    """Removing the slowest VW advances the global clock (fault tolerance:
    a dead worker does not wedge the fleet)."""
    s = WSPClockState(D)
    for i in range(n):
        s.add_worker(f"w{i}")
    for _ in range(D + 1):
        s.complete_wave("w0")
    assert not s.can_proceed("w0")
    # all but w0 are at clock 0; removing them unblocks w0
    for i in range(1, n):
        s.remove_worker(f"w{i}")
    assert s.can_proceed("w0")


def test_rejoin_starts_at_global_clock():
    s = WSPClockState(1)
    s.add_worker("a")
    s.add_worker("b")
    for _ in range(2):
        s.complete_wave("a")
        s.complete_wave("b")
    s.remove_worker("b")
    s.complete_wave("a")
    s.add_worker("b2")           # elastic re-join
    assert s.clocks["b2"] == s.global_clock()
    assert s.can_proceed("b2")


def test_blocking_server_threads():
    """Two threads, D=0: they must alternate in lock step (BSP-like)."""
    srv = WSPClockServer(D=0)
    srv.register("a")
    srv.register("b")
    log = []
    lock = threading.Lock()

    def worker(wid, waves):
        for _ in range(waves):
            assert srv.wait_until_allowed(wid, timeout=10)
            with lock:
                log.append(wid)
            srv.complete_wave(wid)

    ts = [threading.Thread(target=worker, args=("a", 5)),
          threading.Thread(target=worker, args=("b", 5))]
    for t in ts:
        t.start()
    for t in ts:
        t.join(30)
    assert len(log) == 10
    # with D=0 neither worker can be 2 waves ahead at any prefix
    ca = cb = 0
    for wid in log:
        ca += wid == "a"
        cb += wid == "b"
        assert abs(ca - cb) <= 1
