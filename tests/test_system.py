"""End-to-end system tests: the SPMD pipelined wave step (shard_map over a
multi-device mesh) must equal the non-pipelined oracle, for train and decode.

These spawn subprocesses because XLA's host device count is locked at first
import — the main pytest process keeps 1 device (per the assignment, smoke
tests must see 1 device)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCRIPT = os.path.join(ROOT, "tests", "pipeline_equiv_main.py")


def _run(arch, mode):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, SCRIPT, arch, mode],
                       capture_output=True, text=True, timeout=1200, env=env)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    return r.stdout


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "granite-moe-1b-a400m"])
def test_pipelined_train_equals_oracle(arch):
    out = _run(arch, "train")
    assert "max_param_diff" in out


@pytest.mark.parametrize("arch", ["gemma3-1b"])
def test_pipelined_decode_equals_oracle(arch):
    """Covers both serve schedules: baseline vs reference (tolerance) and
    skewed-overlap vs baseline (exact)."""
    out = _run(arch, "decode")
    assert "decode_logits_diff" in out
    assert "decode_overlap_diff=0.000e+00" in out


@pytest.mark.parametrize("arch", ["qwen3-0.6b"])
def test_overlap_schedule_equals_oracle(arch):
    """The software-pipelined (skewed) schedule must be loss- and
    param-identical to the baseline schedule — same compute per microbatch,
    only the comm/compute interleaving changes."""
    out = _run(arch, "overlap")
    assert "overlap_loss_diff=0.000e+00" in out
