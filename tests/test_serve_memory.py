"""The serve memory policy layer (repro.serve.memory): prefix-index
matching and leaf-first LRU eviction, refcounted shared allocation and
copy-on-write in the CacheStore, preemption victim selection — and the
bit-identity invariant: share_prefix/evict/preempt never change a single
emitted token across the three serve families."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Engine, Plan, ServeSpec
from repro.api.report import ServeReport
from repro.api.serving import Request, Scheduler
from repro.configs import ARCHS, reduced
from repro.obs import Tracer
from repro.serve.cache import CacheStore, make_layout
from repro.serve.memory import MemoryManager, PrefixIndex

SERVE_ARCHS = ("qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-3b")

_R = np.random.default_rng(31)
_FAMILY_CASES = [(a, int(_R.integers(0, 1_000))) for a in SERVE_ARCHS]


def _cfg(name: str, **over):
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=256,
                num_microbatches=2)
    if ARCHS[name].attn_type == "swa":
        base["window_size"] = 6        # < max_len: exercise the ring wrap
    base.update(over)
    return reduced(ARCHS[name], **base)


def _streams(rep):
    return {r.rid: list(r.tokens) for r in rep.requests}


def _store(cfg, max_batch=4, max_len=16, page_size=4, max_pages=0):
    return CacheStore(cfg, make_layout(max_batch, max_len,
                                       page_size=page_size,
                                       max_pages=max_pages),
                      dtype=jnp.float32)


# ---------------------------------------------------------------------------
# PrefixIndex
# ---------------------------------------------------------------------------
def test_prefix_index_match_and_insert():
    cfg = _cfg("qwen3-0.6b")
    store = _store(cfg)
    idx = PrefixIndex(4)
    store.alloc(0, 16)                       # 4 pages
    pages = store._owned[0]
    prompt = list(range(10))                 # 2 full pages + 2-token tail
    idx.insert(store, prompt, pages, step=1)
    assert len(idx) == 3                     # the 4th page holds no prompt
    # the whole prompt matches through the partial leaf
    assert idx.match(prompt) == (10, pages[:3])
    # a page-aligned prefix matches full pages only
    assert idx.match(prompt[:8]) == (8, pages[:2])
    # a partial run matches only as the *entire* remainder
    assert idx.match(prompt[:9]) == (8, pages[:2])
    assert idx.match(prompt[:4] + [99] * 6) == (4, pages[:1])
    assert idx.match([99] * 8) == (0, [])
    # idempotent reinsert keeps the original pages indexed
    store.alloc(1, 16)
    idx.insert(store, prompt, store._owned[1], step=2)
    assert idx.match(prompt) == (10, pages[:3])


def test_prefix_index_evict_lru_leaf_first():
    cfg = _cfg("qwen3-0.6b")
    store = _store(cfg, max_batch=2, max_len=16, page_size=4)   # 8 pages
    idx = PrefixIndex(4)
    prompt = list(range(12))
    store.alloc(0, 12)                       # 3 pages
    p = store._owned[0]
    idx.insert(store, prompt, p, step=1)
    cold = store.free(0)                     # all 3 go cold, not free
    assert sorted(cold) == sorted(p)
    assert store.pages_free == 5 and store.pages_cold == 3
    keys = set()
    # reclaim 2: leaf-first means the deepest page goes before its parent
    assert idx.evict_lru(store, need_free=7, evicted_keys=keys) == 2
    assert store.pages_free == 7
    assert idx.match(prompt) == (4, p[:1])
    assert tuple(prompt) in keys             # the full chain was cut
    # protect pins a page the in-flight admission matched
    assert idx.evict_lru(store, need_free=8, protect={p[0]}) == 0
    assert idx.evict_lru(store, need_free=8) == 1
    assert store.pages_free == 8 and len(idx) == 0


def test_evict_skips_pages_still_mapped():
    """A cold parent whose child page is still slot-mapped cannot exist
    (mapping is chain-wise), but a retained page with refcount > 0 must
    never be reclaimed — release defers the free to the last unmap."""
    cfg = _cfg("qwen3-0.6b")
    store = _store(cfg)
    idx = PrefixIndex(4)
    prompt = list(range(8))
    store.alloc(0, 16)
    p = store._owned[0]
    idx.insert(store, prompt, p, step=0)
    # slot 0 still maps every page: nothing is evictable
    assert idx.evict_lru(store, need_free=16) == 0
    store.free(0)
    assert idx.evict_lru(store, need_free=16) == 2


# ---------------------------------------------------------------------------
# CacheStore refcounting / CoW
# ---------------------------------------------------------------------------
def test_store_shared_alloc_counts_distinct_pages():
    cfg = _cfg("qwen3-0.6b")
    store = _store(cfg, max_batch=4, max_len=16, page_size=4, max_pages=8)
    store.alloc(0, 16)
    p = store._owned[0]
    assert store.pages_in_use == 4
    store.alloc(1, 16, shared=p[:2])
    # 2 shared + 2 fresh: 6 *distinct* pages, not 8
    assert store.pages_in_use == 6
    assert store.stats()["pages_shared"] == 2
    assert store._ref[p[0]] == 2 and store._ref[p[2]] == 1
    assert store.can_alloc(16, shared=2) and not store.can_alloc(16)
    # the shared prefix shows up in both block tables
    tab = store._tab
    assert list(tab[0][:2]) == list(tab[1][:2]) == p[:2]
    store.free(0)
    assert store._ref[p[0]] == 1             # slot 1 still maps it
    store.free(1)
    assert store.pages_in_use == 0


def test_store_retained_pages_go_cold_not_free():
    cfg = _cfg("qwen3-0.6b")
    store = _store(cfg, max_batch=2, max_len=8, page_size=4)
    store.alloc(0, 8)
    p = store._owned[0]
    store.retain(p[0])
    cold = store.free(0)
    assert cold == [p[0]]
    assert store.pages_cold == 1 and store.pages_free == 3
    assert store.release(p[0])               # hold dropped -> free
    assert store.pages_free == 4
    # a freed page is no longer a valid shared mapping
    with pytest.raises(ValueError, match="not resident"):
        store.alloc(1, 8, shared=[p[0]])


def test_store_copy_page_device_copy():
    cfg = _cfg("qwen3-0.6b")
    store = _store(cfg, max_batch=2, max_len=8, page_size=4)
    k, v = store.tree["kv_full"]
    store.tree["kv_full"] = (k.at[:, 1].set(7.0), v.at[:, 1].set(3.0))
    store.copy_page(1, 2)
    k2, v2 = store.tree["kv_full"]
    assert np.all(np.asarray(k2[:, 2]) == 7.0)
    assert np.all(np.asarray(v2[:, 2]) == 3.0)
    assert store.cow_copies == 1


def test_pool_less_store_rejects_shared_pages():
    cfg = _cfg("rwkv6-3b")
    store = _store(cfg)
    assert not store._has_pool
    with pytest.raises(ValueError, match="per-slot only"):
        store.alloc(0, 8, shared=[0])


# ---------------------------------------------------------------------------
# ServeReport.page_utilization regression: peak *distinct* pages
# ---------------------------------------------------------------------------
def test_page_utilization_reports_peak_distinct_pages():
    """Regression: utilization is peak_pages / pages_total. The old
    time-averaged page_steps formula (here 32 / (4 * 10) = 0.8) double-
    counted shared pages and answered the wrong sizing question."""
    rep = ServeReport(decode_steps=4, pages_total=10, peak_pages=4,
                      page_steps=32)
    assert rep.page_utilization() == pytest.approx(0.4)
    assert ServeReport().page_utilization() is None
    assert ServeReport(decode_steps=4, pages_total=0,
                       page_steps=32).page_utilization() is None


# ---------------------------------------------------------------------------
# ServeSpec knob validation
# ---------------------------------------------------------------------------
def test_evict_requires_share_prefix():
    cfg = _cfg("qwen3-0.6b")
    with pytest.raises(ValueError, match="share_prefix"):
        Plan(arch=cfg, serve=ServeSpec(prompt_len=8, gen=8, max_batch=2,
                                       page_size=4, evict=True))


# ---------------------------------------------------------------------------
# bit-identity across families: sharing / eviction / preemption never
# change a token
# ---------------------------------------------------------------------------
def _sv(**over):
    base = dict(prompt_len=8, gen=8, max_batch=4, page_size=4, max_pages=12)
    base.update(over)
    return ServeSpec(**base)


def _run(cfg, sv, reqs):
    return Scheduler(Engine(Plan(arch=cfg, serve=sv))).run(reqs)


@pytest.mark.parametrize("arch,seed", _FAMILY_CASES)
def test_shared_prefix_streams_bit_identical(arch, seed):
    """Repeated prompts served through shared refcounted pages emit the
    same tokens as the unshared baseline; the full-attention family peaks
    strictly below it, pool-less families stay inert (counters 0)."""
    cfg = _cfg(arch)
    rng = np.random.default_rng(seed)
    ps = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
          for _ in range(2)]
    mk = lambda: [Request(rid=i, prompt=ps[i % 2].copy(), max_new_tokens=4)
                  for i in range(6)]
    base = _run(cfg, _sv(), mk())
    shared = _run(cfg, _sv(share_prefix=True), mk())
    assert _streams(shared) == _streams(base)
    if cfg.attn_type == "full":
        assert shared.prefix_hit_tokens > 0
        assert shared.pages_shared > 0
        assert shared.peak_pages < base.peak_pages
    else:
        assert shared.pages_total == 0
        assert shared.prefix_hit_tokens == shared.pages_shared == 0
        assert shared.admit_blocked == 0


def test_cow_on_fully_matched_partial_page():
    """A prompt ending inside a page shares it by copy-on-write: the
    sharer decodes into its copy, the indexed original stays immutable,
    and the streams stay bit-identical."""
    cfg = _cfg("qwen3-0.6b")
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
    mk = lambda: [Request(rid=i, prompt=prompt.copy(), max_new_tokens=4)
                  for i in range(4)]
    base = _run(cfg, _sv(), mk())
    shared = _run(cfg, _sv(share_prefix=True), mk())
    assert _streams(shared) == _streams(base)
    assert shared.cow_copies > 0
    assert shared.prefix_hit_tokens > 0


@pytest.mark.parametrize("arch,seed", _FAMILY_CASES)
def test_evict_readmit_streams_bit_identical(arch, seed):
    """Cold indexed pages reclaimed under pressure, then the evicted
    prompt readmitted: recompute-on-readmit, identical streams. rid 0
    retires first so its pages are the LRU victims; rid 6 repeats its
    prompt after the pool churned."""
    cfg = _cfg(arch)
    rng = np.random.default_rng(seed)
    ps = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
          for _ in range(6)]
    order = ps + [ps[0]]
    gens = [2, 6, 6, 6, 6, 6, 2]
    mk = lambda: [Request(rid=i, prompt=order[i].copy(),
                          max_new_tokens=gens[i]) for i in range(7)]
    base = _run(cfg, _sv(), mk())
    ev = _run(cfg, _sv(share_prefix=True, evict=True), mk())
    assert _streams(ev) == _streams(base)
    if cfg.attn_type == "full":
        assert ev.evictions > 0
        assert ev.readmit_recomputes > 0
    else:
        assert ev.evictions == ev.readmit_recomputes == 0


@pytest.mark.parametrize("arch,seed", _FAMILY_CASES)
def test_preempt_streams_bit_identical(arch, seed):
    """Under pool pressure a victim is preempted and replayed from its
    prompt instead of blocking admission; the replayed stream is
    bit-identical and blocked rounds do not increase."""
    cfg = _cfg(arch)
    rng = np.random.default_rng(seed)
    mk = lambda: [Request(rid=i,
                          prompt=rng.integers(0, cfg.vocab_size, 8,
                                              dtype=np.int32))
                  for i in range(4)]
    reqs = mk()
    copies = lambda: [Request(r.rid, r.prompt.copy(), r.max_new_tokens)
                      for r in reqs]
    base = _run(cfg, _sv(), copies())
    pre = _run(cfg, _sv(preempt=True), copies())
    assert _streams(pre) == _streams(base)
    if cfg.attn_type == "full":
        assert pre.preemptions > 0
        assert pre.admit_blocked <= base.admit_blocked
    else:
        assert pre.preemptions == 0 and pre.admit_blocked == 0


def test_shared_prefix_sampled_streams_bit_identical():
    """Bit-identity holds under sampling too: token picks are keyed by
    (sample_seed, rid, k), independent of sharing and co-batching."""
    cfg = _cfg("qwen3-0.6b")
    rng = np.random.default_rng(13)
    prompt = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    mk = lambda: [Request(rid=i, prompt=prompt.copy(), max_new_tokens=4)
                  for i in range(5)]
    kw = dict(temperature=1.0, sample_seed=5)
    base = _run(cfg, _sv(**kw), mk())
    shared = _run(cfg, _sv(share_prefix=True, evict=True, preempt=True,
                           **kw), mk())
    assert _streams(shared) == _streams(base)
    assert shared.prefix_hit_tokens > 0


# ---------------------------------------------------------------------------
# preemption victim selection
# ---------------------------------------------------------------------------
class _FakeReq:
    def __init__(self, rid, deadline=0):
        self.rid, self.deadline = rid, deadline


class _FakeStats:
    def __init__(self, tokens):
        self.tokens = tokens


class _FakeSlot:
    def __init__(self, rid, tokens, limit=8, deadline=0):
        self.req = _FakeReq(rid, deadline)
        self.stats = _FakeStats(list(tokens))
        self.limit = limit


def test_victim_policies():
    cfg = _cfg("qwen3-0.6b")
    store = _store(cfg, max_batch=4, max_len=16, page_size=4)
    store.alloc(0, 16)
    store.alloc(1, 16)
    fifo = MemoryManager(store, preempt=True, policy="fifo")
    # fifo: fewest generated tokens (cheapest replay), rid tie-break
    active = {0: _FakeSlot(0, [1, 2, 3]), 1: _FakeSlot(1, [1])}
    assert fifo.victim(active, step=5, need_fresh=4) == 1
    # deadline: most slack first; no deadline = infinite slack
    edf = MemoryManager(store, preempt=True, policy="deadline")
    active = {0: _FakeSlot(0, [1, 2, 3], deadline=0),
              1: _FakeSlot(1, [1], limit=4, deadline=30)}
    assert edf.victim(active, step=5, need_fresh=4) == 0
    # a victim that cannot cover the shortfall is never nominated
    assert fifo.victim(active, step=5, need_fresh=64) is None
    off = MemoryManager(store, preempt=False)
    assert off.victim(active, step=5, need_fresh=1) is None


def test_pool_less_manager_is_inert():
    cfg = _cfg("rwkv6-3b")
    store = _store(cfg)
    mm = MemoryManager(store, share_prefix=True, evict=True, preempt=True)
    assert not (mm.share_prefix or mm.evict or mm.preempt)
    assert mm.plan_admit(np.arange(8), 16) == (0, [], 0)
    assert mm.make_room(10**6)
    assert mm.admit(0, np.arange(8), 16, 0, [], step=0) == 0
    assert mm.victim({0: _FakeSlot(0, [1])}, step=0, need_fresh=1) is None


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------
def test_memory_counters_reach_telemetry():
    cfg = _cfg("qwen3-0.6b")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    reqs = [Request(rid=i, prompt=prompt.copy()) for i in range(4)]
    plan = Plan(arch=cfg, serve=_sv(share_prefix=True, evict=True,
                                    preempt=True))
    rep = Scheduler(Engine(plan, tracer=Tracer())).run(reqs)
    tel = rep.telemetry
    assert tel is not None
    assert tel.gauges["serve/prefix_hit_rate"] > 0
    assert tel.counters.get("serve/preemptions", 0) == rep.preemptions
    assert tel.counters.get("serve/evictions", 0) == rep.evictions
