"""Router invariants (repro.serve.router): plan validation for replica
fleets, FIFO no-starvation, prefix affinity (one replica owns a shared
prefix, zero cross-replica duplicate pages), bit-identical token streams
vs the single-replica Scheduler oracle on all three serve families,
replica-crash chaos re-dispatch, and ServeReport.merge() regression
against the single-replica degenerate case."""
import numpy as np
import pytest

from repro.api import (ClusterSpec, Engine, FaultPlan, PartitionSpec, Plan,
                       ReplicaDown, ReplicaSpec, RunSpec, ServeSpec)
from repro.api.report import ServeReport
from repro.api.serving import Request, Scheduler
from repro.configs import ARCHS, reduced
from repro.obs import Tracer
from repro.serve.router import ROUTER_POLICIES, Router

SERVE_ARCHS = ("qwen3-0.6b", "h2o-danube-1.8b", "rwkv6-3b")


def _cfg(name: str = "qwen3-0.6b", **over):
    base = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=256,
                num_microbatches=2)
    if ARCHS[name].attn_type == "swa":
        base["window_size"] = 6
    base.update(over)
    return reduced(ARCHS[name], **base)


def _sv(**over):
    base = dict(prompt_len=8, gen=4, max_batch=4, page_size=4)
    base.update(over)
    return ServeSpec(**base)


def _reqs(seed, n, *, vocab=256, pmax=8, gen=4, shared=0, deadline=False):
    """n seeded requests; the first `shared` share one full-page prompt."""
    rng = np.random.default_rng(seed)
    common = rng.integers(0, vocab, pmax, dtype=np.int32)
    out = []
    for i in range(n):
        if i < shared:
            prompt = common.copy()
        else:
            prompt = rng.integers(0, vocab, int(rng.integers(2, pmax + 1)),
                                  dtype=np.int32)
        out.append(Request(rid=i, prompt=prompt,
                           max_new_tokens=int(rng.integers(1, gen + 1)),
                           deadline=int(rng.integers(gen, 6 * gen))
                           if deadline else 0))
    return out


def _streams(report):
    return {s.rid: list(s.tokens) for s in report.requests}


# ---------------------------------------------------------------------------
# Plan validation: the data-parallel serve lift
# ---------------------------------------------------------------------------
def test_cluster_plan_validation():
    cfg = _cfg()
    # replicas ride partition.data on the threads backend
    plan = Plan(arch=cfg, serve=_sv(), partition=PartitionSpec(data=2))
    assert "replicas=2" in plan.describe()
    with pytest.raises(ValueError, match="data"):
        Plan(arch=cfg, serve=_sv(), partition=PartitionSpec(data=0))
    # spmd serve keeps one replica on the mesh
    with pytest.raises(ValueError, match="data-parallel serve"):
        Plan(arch=cfg, serve=_sv(),
             run=RunSpec(backend="spmd"),
             partition=PartitionSpec(stages=2, tp=1, data=2, devices=4))
    with pytest.raises(ValueError, match="data-parallel serve"):
        Plan(arch=cfg, serve=_sv(replicas=(ReplicaSpec(), ReplicaSpec())),
             run=RunSpec(backend="spmd"),
             partition=PartitionSpec(stages=2, tp=1, data=1, devices=2))
    # per-replica specs must match the fleet size and fit the ceiling
    with pytest.raises(ValueError, match="replicas"):
        Plan(arch=cfg, serve=_sv(replicas=(ReplicaSpec(max_batch=2),)),
             partition=PartitionSpec(data=2))
    with pytest.raises(ValueError, match="max_batch"):
        Plan(arch=cfg, serve=_sv(replicas=(ReplicaSpec(max_batch=8),
                                           ReplicaSpec(max_batch=2))),
             partition=PartitionSpec(data=2))
    # a whimpy replica still has to hold one worst-case request
    with pytest.raises(ValueError, match="worst-case"):
        Plan(arch=cfg, serve=_sv(max_pages=24,
                                 replicas=(ReplicaSpec(max_batch=4),
                                           ReplicaSpec(max_batch=2,
                                                       max_pages=1))),
             partition=PartitionSpec(data=2))
    # topology prices the Router; other cluster knobs stay train-side
    Plan(arch=cfg, serve=_sv(), partition=PartitionSpec(data=2),
         cluster=ClusterSpec(topology="hetero"))
    with pytest.raises(ValueError, match="batches requests"):
        Plan(arch=cfg, serve=_sv(), partition=PartitionSpec(data=2),
             cluster=ClusterSpec(num_vw=2, topology="hetero"))


def test_replica_down_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="non-negative"):
        FaultPlan(seed=0, events=(ReplicaDown(replica=-1, step=0),))
    # a single-replica plan has no survivor to re-dispatch onto
    with pytest.raises(ValueError, match="survivor"):
        Plan(arch=cfg, serve=_sv(),
             faults=FaultPlan(seed=0, events=(ReplicaDown(0, 1),)))
    with pytest.raises(ValueError, match="replica"):
        Plan(arch=cfg, serve=_sv(), partition=PartitionSpec(data=2),
             faults=FaultPlan(seed=0, events=(ReplicaDown(5, 1),)))
    # ReplicaDown is a serving fault
    with pytest.raises(ValueError, match="serving fault"):
        Plan(arch=cfg, run=RunSpec(max_waves=1, batch=4, seq=16),
             faults=FaultPlan(seed=0, events=(ReplicaDown(0, 1),)))
    # sample_cluster stays inside the fleet
    fp = FaultPlan.sample_cluster(3, replicas=3)
    (ev,) = fp.of_type(ReplicaDown)
    assert 0 <= ev.replica < 3 and ev.step >= 1


def test_router_rejects_bad_plans():
    cfg = _cfg()
    with pytest.raises(ValueError, match="ServeSpec"):
        Router(Plan(arch=cfg, run=RunSpec(max_waves=1, batch=4, seq=16)))
    with pytest.raises(ValueError, match="policy"):
        Router(Plan(arch=cfg, serve=_sv()), policy="round_robin")
    assert set(ROUTER_POLICIES) == {"least_loaded", "deadline"}


# ---------------------------------------------------------------------------
# Dispatch invariants
# ---------------------------------------------------------------------------
def test_fifo_no_starvation_under_pressure():
    """More requests than the whole fleet has slots: every request
    retires, none fails, and the merged report covers all rids."""
    plan = Plan(arch=_cfg(), partition=PartitionSpec(data=2),
                serve=_sv(max_batch=2,
                          replicas=(ReplicaSpec(max_batch=2),
                                    ReplicaSpec(max_batch=1))))
    reqs = _reqs(5, 9)
    rep = Router(plan).run(reqs)
    assert rep.failed_requests == 0
    assert sorted(s.rid for s in rep.requests) == list(range(9))
    assert rep.tokens_out == sum(r.max_new_tokens for r in reqs)
    assert rep.router["dispatches"] == 9
    assert rep.router["queue_depth_peak"] == 9


def test_affinity_pins_shared_prefix_to_one_replica():
    """Identical page-aligned prefixes land on one replica: its prefix
    index holds the shared pages, every other replica's pool stays
    untouched — zero cross-replica duplicate pages."""
    plan = Plan(arch=_cfg(), partition=PartitionSpec(data=3),
                serve=_sv(share_prefix=True))
    router = Router(plan)
    reqs = _reqs(7, 6, shared=6)
    rep = router.run(reqs)
    assert rep.failed_requests == 0
    assert rep.router["affinity_hits"] >= 5      # all but the first
    assert rep.prefix_hit_tokens > 0
    touched = [r.idx for r in router.replicas
               if r.store.peak_pages > 0 or len(r.mm.index.by_page)]
    assert len(touched) == 1, f"shared prefix spread to {touched}"
    # the shared pages exist once, on that replica
    owner = router.replicas[touched[0]]
    assert len(owner.mm.index.by_page) > 0


def test_topology_prices_dispatch():
    """A fast-but-far replica loses to a near whimpy one: with the client
    at the ps host (vw0's node) and replica 1 behind the inter-node link,
    ties break toward vw0 and only load pressure pushes traffic across."""
    from repro.dist.topology import ClusterTopology, LinkSpec, Pod
    slow = LinkSpec("far", gbps=0.1, latency_s=5.0)   # absurdly far
    topo = ClusterTopology([Pod("n0", ("vw0",)), Pod("n1", ("vw1",))],
                           inter=slow)
    plan = Plan(arch=_cfg(), partition=PartitionSpec(data=2),
                serve=_sv(max_batch=2,
                          replicas=(ReplicaSpec(max_batch=1),
                                    ReplicaSpec(max_batch=2))))
    router = Router(plan.replace(cluster__topology=topo))
    assign = router._dispatch(_reqs(9, 3))
    # replica 0 is whimpy (1 slot) but near: it still wins every request
    # because 5 s of link latency dwarfs any queueing advantage
    assert len(assign[0]) == 3 and len(assign[1]) == 0
    # without the topology the same fleet spreads by load
    flat = Router(plan)
    spread = flat._dispatch(_reqs(9, 3))
    assert len(spread[1]) > 0


def test_deadline_policy_dispatches_by_slack():
    plan = Plan(arch=_cfg(), partition=PartitionSpec(data=2),
                serve=_sv(max_batch=2, replicas=(ReplicaSpec(max_batch=2),
                                                 ReplicaSpec(max_batch=2))))
    router = Router(plan, policy="deadline")
    reqs = [Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=2, deadline=100),
            Request(rid=1, prompt=np.arange(4, dtype=np.int32) + 1,
                    max_new_tokens=2, deadline=3)]
    assign = router._dispatch(reqs)
    # the tight-deadline request dispatched first -> emptiest replica (0)
    assert assign[0][0].rid == 1
    for r in router.replicas:
        assert r.scheduler.policy == "deadline"


# ---------------------------------------------------------------------------
# Bit-identity: routing never changes a token stream
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", SERVE_ARCHS)
def test_router_streams_match_single_replica_oracle(arch):
    cfg = _cfg(arch)
    paged = dict(page_size=4) if arch != "rwkv6-3b" else dict(page_size=0)
    sv = _sv(temperature=0.7, share_prefix=arch == "qwen3-0.6b", **paged)
    reqs = _reqs(11, 6, shared=2)
    import dataclasses
    plan = Plan(arch=cfg, partition=PartitionSpec(data=2),
                cluster=ClusterSpec(topology="2node"),
                serve=dataclasses.replace(
                    sv, replicas=(ReplicaSpec(max_batch=4),
                                  ReplicaSpec(max_batch=2))))
    got = Router(plan).run([Request(rid=r.rid, prompt=np.asarray(r.prompt),
                                    max_new_tokens=r.max_new_tokens)
                            for r in reqs])
    oracle = Scheduler(Engine(Plan(arch=cfg, serve=sv))).run(
        [Request(rid=r.rid, prompt=np.asarray(r.prompt),
                 max_new_tokens=r.max_new_tokens) for r in reqs])
    assert _streams(got) == _streams(oracle)


def test_chaos_replica_down_redispatch_no_divergence():
    """Kill one replica mid-decode: unfinished requests re-dispatch to the
    survivor and every stream still matches the single-replica oracle."""
    cfg = _cfg()
    sv = _sv(max_batch=2)
    reqs = _reqs(13, 6)
    plan = Plan(arch=cfg, partition=PartitionSpec(data=2),
                faults=FaultPlan(seed=0, events=(ReplicaDown(1, 1),)),
                serve=sv)
    tr = Tracer()
    rep = Router(plan, tracer=tr).run(
        [Request(rid=r.rid, prompt=np.asarray(r.prompt),
                 max_new_tokens=r.max_new_tokens) for r in reqs])
    assert rep.router["replica_downs"] == 1
    assert rep.router["rounds"] >= 2           # survivors re-dispatched
    assert rep.router["rebalances"] > 0
    assert rep.failed_requests == 0
    assert sorted(s.rid for s in rep.requests) == list(range(6))
    oracle = Scheduler(Engine(Plan(arch=cfg, serve=sv))).run(
        [Request(rid=r.rid, prompt=np.asarray(r.prompt),
                 max_new_tokens=r.max_new_tokens) for r in reqs])
    assert _streams(rep) == _streams(oracle)
    snap = tr.metrics.snapshot()
    assert snap["counters"]["fault/replica_downs"] == 1


def test_all_replicas_down_raises():
    plan = Plan(arch=_cfg(), partition=PartitionSpec(data=2),
                faults=FaultPlan(seed=0, events=(ReplicaDown(0, 0),
                                                 ReplicaDown(1, 0))),
                serve=_sv(max_batch=2))
    with pytest.raises(RuntimeError, match="down|no requests|spin"):
        Router(plan).run(_reqs(17, 4))


# ---------------------------------------------------------------------------
# ServeReport.merge
# ---------------------------------------------------------------------------
def test_merge_degenerate_single_replica():
    """merge([r]) reproduces the single report's derived metrics."""
    plan = Plan(arch=_cfg(), serve=_sv())
    single = Scheduler(Engine(plan)).run(_reqs(19, 5))
    merged = ServeReport.merge([single], wall_s=single.wall_s)
    assert merged.occupancy() == pytest.approx(single.occupancy())
    assert merged.page_utilization() == pytest.approx(
        single.page_utilization())
    assert merged.tokens_out == single.tokens_out
    assert merged.tokens_per_s() == pytest.approx(single.tokens_per_s())
    assert _streams(merged) == _streams(single)


def test_merge_weights_capacity_by_decode_steps():
    a = ServeReport(arch="x", backend="threads", max_batch=4,
                    decode_steps=10, slot_steps=20, pages_total=10,
                    peak_pages=5, wall_s=1.0)
    b = ServeReport(arch="x", backend="threads", max_batch=2,
                    decode_steps=5, slot_steps=10, pages_total=4,
                    peak_pages=4, wall_s=2.0)
    m = ServeReport.merge([a, b], router={"policy": "least_loaded"})
    # occupancy = (20+10) / (10*4 + 5*2) = 30/50
    assert m.occupancy() == pytest.approx(30 / 50)
    # page utilization = (5+4) / (10+4)
    assert m.page_utilization() == pytest.approx(9 / 14)
    assert m.wall_s == 2.0                      # replicas ran concurrently
    assert m.router["policy"] == "least_loaded"
    with pytest.raises(ValueError, match="at least one"):
        ServeReport.merge([])
